"""Speculative decoding: pluggable drafters for the packed draft-and-verify
dispatch (:meth:`repro.serve.engine.ServeEngine._spec_tick`).

The scalar-vector split, one more time: proposing candidate tokens is cheap
irregular *scalar* work (a host-side suffix match, or a shallow model), and
verifying them is exactly the wide *vector* work the engine already has — a
ragged packed dispatch scoring every (slot, offset) row in one kernel pass.
Speculation reconfigures the serving loop the same way merge mode
reconfigures prefill: the per-step work changes shape, the machinery does
not.  A drafter proposes up to ``k`` tokens per decoding slot; the engine
feeds ``[last_token, draft_1 .. draft_d]`` per slot through ONE
``packed_step`` (dense or block-paged — the same descriptors drive both),
samples all ``d+1`` target positions with the standard per-position
``fold_in(key(seed), pos)`` keys, and commits the longest prefix of drafts
that EXACTLY match the seeded target draws (:func:`repro.serve.sampling
.spec_verify`).

Acceptance is exact-match by construction, not min(1, p/q) rejection
sampling: the engine's sampler is deterministic given (context, seed,
position), so the target "distribution" at each position is a point mass on
the seeded draw and the stochastic acceptance rule degenerates to the
equality indicator.  That is what makes speculation *invisible*: a seeded
stream with speculation on is bit-identical to the same stream with
speculation off, because every committed token IS the token the sequential
engine would have sampled (the verify pass replays the same logits — the
packed dispatch is bitwise equal to sequential decode — and the same PRNG
keys).  Greedy requests get prefix-match on argmax agreement automatically:
``smode 0`` targets are the argmax rows, no threefry enters the program.

Rejected tails need no KV rollback: a rejected draft's K/V was scattered at
a position ``>= cur_len`` after the commit, every attention mask hides
positions beyond ``cur_len``, and the next dispatch's scatters overwrite
them — the same garbage-tolerance argument slot reuse already relies on.
In paged mode nothing is released either: admission reserved the whole
worst-case table, and the verify rows only touch positions inside it.

Two stock drafters:

* :class:`NGramDrafter` — prompt-lookup decoding: zero extra weights, a
  longest-suffix n-gram match against the request's OWN prompt + generated
  tokens.  Each lookup proposes one token and appends it to the working
  context before the next lookup ("cyclic extension"), so a repeating
  pattern unrolls to the full depth ``k`` instead of truncating at the end
  of the matched region.
* :class:`ModelDrafter` — a shallow draft model (a config from
  ``repro/configs`` or a layer-truncated view of the target's own params)
  with its OWN KV cache over the same slot layout, caught up through the
  same packed machinery and rolled greedily ``k`` steps.  Draft-cache
  rollback is the same masking argument: speculative positions are
  re-scattered from committed tokens at the next catch-up.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Mapping, Optional, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

# the drafter's catch-up pack sizes: same 1.5x ladder philosophy as the
# engine's _T_BUCKETS (kept local — the drafter compiles its own programs)
_PACK_BUCKETS = (8, 16, 24, 32, 48, 64, 96, 128)


def _bucket(t: int) -> int:
    for b in _PACK_BUCKETS:
        if t <= b:
            return b
    b = _PACK_BUCKETS[-1]
    while b < t:
        b *= 2
    return b


@dataclass(frozen=True)
class SpeculateConfig:
    """Engine-level speculation configuration.

    ``mode`` selects the drafter ("ngram" or "draft"); ``k`` caps the
    proposal depth per slot; ``adaptive`` lets the engine shrink/grow each
    slot's depth inside {1, 2, 4, .., k} from its measured acceptance EWMA
    (a slot the drafter cannot predict degrades to depth 1 — one wasted
    verify row — instead of k).  ``draft_arch`` names a config from
    ``repro/configs`` for the draft model; ``None`` with mode="draft"
    means a ``draft_layers``-deep truncation of the TARGET's own params
    (the zero-training draft).  ``tenants`` holds per-tenant overrides:
    ``{"tenant_a": False}`` turns speculation off for that tenant's
    requests (their slots ride the verify dispatch at depth 0)."""

    mode: str = "ngram"
    k: int = 8
    max_ngram: int = 8
    adaptive: bool = True
    draft_arch: Optional[str] = None
    draft_layers: int = 1
    draft_reduced: bool = False
    tenants: Mapping[str, bool] = field(default_factory=dict)

    def __post_init__(self):
        if self.mode not in ("ngram", "draft"):
            raise ValueError(f"speculate mode must be ngram|draft, got {self.mode!r}")
        if self.k < 1:
            raise ValueError(f"speculate k must be >= 1, got {self.k}")
        object.__setattr__(self, "tenants", dict(self.tenants))

    @classmethod
    def parse(cls, spec: str, **kw) -> Optional["SpeculateConfig"]:
        """CLI string → config: ``off`` | ``ngram`` | ``draft`` |
        ``draft:<arch>`` (extra kwargs override fields)."""
        spec = spec.strip()
        if spec in ("off", "none", ""):
            return None
        if spec == "ngram":
            return cls(mode="ngram", **kw)
        if spec == "draft":
            return cls(mode="draft", **kw)
        if spec.startswith("draft:"):
            return cls(mode="draft", draft_arch=spec.split(":", 1)[1], **kw)
        raise ValueError(
            f"unknown --speculate value {spec!r} (off|ngram|draft[:<arch>])"
        )

    @classmethod
    def coerce(cls, spec) -> Optional["SpeculateConfig"]:
        if spec is None or isinstance(spec, cls):
            return spec
        if isinstance(spec, str):
            return cls.parse(spec)
        raise TypeError(f"speculate must be str|SpeculateConfig|Drafter, got {type(spec)}")

    def enabled_for(self, tenant: Optional[str]) -> bool:
        if tenant is None:
            return True
        return bool(self.tenants.get(tenant, True))


@runtime_checkable
class Drafter(Protocol):
    """What the engine needs from a drafter.  ``propose`` sees each slot's
    FULL committed context (prompt + every harvested token — the spec tick
    is value-blocking, so nothing is in flight) and the per-slot requested
    depths; it returns per-slot proposal lists of AT MOST those depths
    (shorter is fine — the engine shrinks the slot's depth to what it
    got)."""

    name: str

    def setup(self, backend, batch_slots: int, max_len: int, vocab_size: int) -> None: ...

    def reset_slot(self, slot: int) -> None: ...

    def propose(
        self, ctxs: Sequence[Optional[np.ndarray]], depths: np.ndarray
    ) -> list[list[int]]: ...

    def prewarm(self) -> None: ...


class NGramDrafter:
    """Prompt-lookup drafter: longest-suffix n-gram match with cyclic
    extension.  Zero weights, zero device state — pure host scalar work
    riding alongside the vector verify dispatch.

    One proposal step finds the LATEST earlier occurrence of the longest
    suffix (length ``max_n`` down to 1) of the working context and copies
    the token that followed it.  The proposal is appended to the working
    context before the next step, so a period-p cycle in the stream unrolls
    to the full requested depth instead of stopping where the matched
    region ends — measured on this repo's streams that roughly doubles the
    mean committed run.  Tokens are matched as bytes when the vocab fits
    (one C-speed ``rfind`` per suffix length), as int arrays otherwise."""

    name = "ngram"

    def __init__(self, max_n: int = 8):
        self.max_n = max(1, int(max_n))
        self._bytes = False

    def setup(self, backend, batch_slots, max_len, vocab_size) -> None:
        self._bytes = vocab_size <= 256

    def reset_slot(self, slot: int) -> None:
        pass

    def prewarm(self) -> None:
        pass

    # -- one lookup step -------------------------------------------------
    @staticmethod
    def _next_bytes(work: bytes, max_n: int) -> Optional[int]:
        ln = len(work)
        for n in range(min(max_n, ln - 1), 0, -1):
            suf = work[ln - n:]
            idx = work.rfind(suf, 0, ln - 1)  # occurrence ending before the end
            if idx >= 0:
                return work[idx + n]
        return None

    @staticmethod
    def _next_ints(work: np.ndarray, max_n: int) -> Optional[int]:
        ln = len(work)
        for n in range(min(max_n, ln - 1), 0, -1):
            suf = work[ln - n:]
            win = np.lib.stride_tricks.sliding_window_view(work, n)[: ln - n]
            hits = np.flatnonzero((win == suf).all(axis=1))
            if hits.size:
                return int(work[hits[-1] + n])
        return None

    def _one(self, ctx: np.ndarray, depth: int) -> list[int]:
        out: list[int] = []
        if self._bytes:
            work = bytes(int(t) & 0xFF for t in ctx)
            for _ in range(depth):
                nxt = self._next_bytes(work, self.max_n)
                if nxt is None:
                    break
                out.append(nxt)
                work += bytes([nxt])
        else:
            work = np.asarray(ctx, np.int64)
            for _ in range(depth):
                nxt = self._next_ints(work, self.max_n)
                if nxt is None:
                    break
                out.append(nxt)
                work = np.append(work, nxt)
        return out

    def propose(self, ctxs, depths) -> list[list[int]]:
        return [
            self._one(c, int(d)) if c is not None and d > 0 else []
            for c, d in zip(ctxs, depths)
        ]


class ModelDrafter:
    """Shallow-model drafter with its own per-slot KV cache.

    The draft model mirrors the engine's slot layout.  Each ``propose``
    call first CATCHES UP: every context token not yet in the draft cache
    is fed through one packed dispatch (the same ragged descriptors the
    engine's prefill pack uses — token/slot/position triples, bucketed T),
    whose per-slot last row argmaxes the first draft token.  It then ROLLS
    greedily: a fused scan of draft decode+argmax steps proposes the rest.

    Speculative pollution of the draft cache needs no rollback: ``fed``
    only advances over COMMITTED tokens, the roll's scattered K/V beyond
    ``fed`` is invisible to any masked read (kpos <= tok_pos / cur_len),
    and the next catch-up re-scatters the committed truth over those
    positions — the same argument that lets the target cache skip rollback
    for rejected verify rows."""

    name = "draft"

    def __init__(self, model, params):
        self.model = model
        self._params_in = params
        self.backend = None

    @classmethod
    def truncated(cls, model, params, n_layers: int = 1) -> "ModelDrafter":
        """Zero-training draft: the first ``n_layers`` of the TARGET's own
        stack (embedding, truncated blocks, final norm — the params' block
        leaves are sliced on their leading layer axis) as a standalone
        shallow model."""
        cfg = replace(model.cfg, n_layers=n_layers)
        sliced = dict(params)
        sliced["blocks"] = jax.tree.map(lambda a: a[:n_layers], params["blocks"])
        from repro.models.model import LM

        return cls(LM(cfg), sliced)

    # -- engine binding --------------------------------------------------
    def setup(self, backend, batch_slots, max_len, vocab_size) -> None:
        self.backend = backend
        self.B = batch_slots
        self.max_len = max_len
        self.params = backend.put_params(self.model, self._params_in)
        self.cache = backend.put_cache(
            self.model, self.model.init_cache(batch_slots, max_len)
        )
        self.fed = np.zeros(batch_slots, np.int64)
        self._shapes: set[int] = set()
        self._catch = backend.jit(self._catch_fn, donate_argnums=(1,))
        self._roll = backend.jit(
            self._roll_fn, donate_argnums=(1,), static_argnames=("n_steps",)
        )

    def reset_slot(self, slot: int) -> None:
        self.fed[slot] = 0

    def _catch_fn(self, params, cache, desc, out_rows):
        logits, cache = self.model.packed_step(
            params, cache, desc[0], desc[1], desc[2], out_rows=out_rows
        )
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def _roll_fn(self, params, cache, tok, cl, act, n_steps: int = 1):
        def step(carry, _):
            t, c, ca = carry
            logits, ca = self.model.decode_step(
                params, ca, {"tokens": t[:, None]}, c
            )
            nt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            t = jnp.where(act.astype(bool), nt, t)
            return (t, c + act, ca), t

        (_, _, cache), toks = jax.lax.scan(
            step, (tok, cl, cache), None, length=n_steps
        )
        return toks, cache

    def prewarm(self) -> None:
        """Compile the steady-state catch-up buckets and every roll depth.
        Admission-sized catch-ups (whole prompts) compile lazily — the
        engine's warmup drain absorbs them like its own prefill buckets."""
        for tb in [b for b in _PACK_BUCKETS if b <= _bucket(4 * self.B)]:
            desc = np.zeros((3, tb), np.int32)
            desc[2] = self.max_len  # all scatters dropped
            first, self.cache = self._catch(
                self.params, self.cache, self.backend.put_host(desc),
                self.backend.put_host(np.zeros(self.B, np.int32)),
            )
            jax.block_until_ready(first)
            self._shapes.add(tb)
        z = self.backend.put_host(np.zeros(self.B, np.int32))
        n = 1
        while True:
            toks, self.cache = self._roll(
                self.params, self.cache, z, z, z, n_steps=n
            )
            jax.block_until_ready(toks)
            if n >= 8:
                break
            n *= 2

    def propose(self, ctxs, depths) -> list[list[int]]:
        out: list[list[int]] = [[] for _ in range(self.B)]
        live = [
            i for i in range(self.B)
            if ctxs[i] is not None and int(depths[i]) > 0
        ]
        if not live:
            return out
        # catch-up pack: feed every committed-but-unfed token; a slot's
        # last fed row predicts its next position (= first draft)
        entries: list[tuple[int, int, int]] = []
        out_rows = np.zeros(self.B, np.int32)
        roll_cl = np.zeros(self.B, np.int32)
        act = np.zeros(self.B, np.int32)
        for i in live:
            c = ctxs[i]
            ln = len(c)
            fed = int(self.fed[i])
            if not 0 < fed <= ln:
                fed = 0  # slot reused or rolled back: refeed from scratch
            if fed == ln:
                fed = ln - 1  # nothing new: refeed the last token (idempotent)
            for pos in range(fed, ln):
                entries.append((int(c[pos]), i, pos))
            out_rows[i] = len(entries) - 1
            roll_cl[i] = ln
            act[i] = 1
            self.fed[i] = ln
        tb = _bucket(len(entries))
        desc = np.zeros((3, tb), np.int32)
        desc[2] = self.max_len  # padding rows: dropped scatters
        for t, (tok, sl, pos) in enumerate(entries):
            desc[0, t], desc[1, t], desc[2, t] = tok, sl, pos
        first, self.cache = self._catch(
            self.params, self.cache, self.backend.put_host(desc),
            self.backend.put_host(out_rows),
        )
        self._shapes.add(tb)
        maxd = max(int(depths[i]) for i in live)
        if maxd > 1:
            n = 1
            while n < maxd - 1:
                n *= 2
            rolls, self.cache = self._roll(
                self.params, self.cache, first,
                self.backend.put_host(roll_cl), self.backend.put_host(act),
                n_steps=n,
            )
            rolls_h = np.asarray(rolls)  # [n, B]
        else:
            rolls_h = np.zeros((0, self.B), np.int32)
        first_h = np.asarray(first)
        for i in live:
            d = int(depths[i])
            out[i] = [int(first_h[i])] + [int(t) for t in rolls_h[: d - 1, i]]
        return out


def build_drafter(cfg: SpeculateConfig, model, params) -> Drafter:
    """Engine-side drafter construction from a :class:`SpeculateConfig`.

    ``mode="draft"`` with ``draft_arch=None`` truncates the target's own
    params (no extra weights anywhere); with an arch name it builds that
    config fresh — random-initialized, a placeholder for loading real
    distilled draft weights."""
    if cfg.mode == "ngram":
        return NGramDrafter(max_n=cfg.max_ngram)
    if cfg.draft_arch is None:
        return ModelDrafter.truncated(model, params, n_layers=cfg.draft_layers)
    from repro.configs import get_arch
    from repro.models.model import LM

    dcfg = get_arch(cfg.draft_arch)
    if cfg.draft_reduced:
        dcfg = dcfg.reduced()
    if dcfg.vocab_size != model.cfg.vocab_size:
        raise ValueError(
            f"draft arch {cfg.draft_arch!r} vocab {dcfg.vocab_size} != "
            f"target vocab {model.cfg.vocab_size}"
        )
    dmodel = LM(dcfg)
    return ModelDrafter(dmodel, dmodel.init(jax.random.key(0)))
