"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before any jax initialization and only then calls this.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType

from repro.dist.sharding import MeshInfo


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def mesh_info_for(mesh) -> MeshInfo:
    """Axis roles for a production mesh (pod folds into the batch axes —
    MERGE-mode semantics; SPLIT tenants use SpatzformerCluster.pod_info)."""
    if "pod" in mesh.axis_names:
        return MeshInfo(mesh, batch_axes=("pod", "data"))
    return MeshInfo(mesh, batch_axes=("data",))
