"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before any jax initialization and only then calls this.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType

from repro.core.modes import Mode
from repro.dist.sharding import MeshInfo, serving_mesh_info


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def mesh_info_for(mesh) -> MeshInfo:
    """Axis roles for a production mesh (pod folds into the batch axes —
    MERGE-mode semantics; SPLIT tenants use SpatzformerCluster.pod_info)."""
    if "pod" in mesh.axis_names:
        return MeshInfo(mesh, batch_axes=("pod", "data"))
    return MeshInfo(mesh, batch_axes=("data",))


def serving_mesh_infos(mode: Mode | str, devices=None) -> list[MeshInfo]:
    """Map SPLIT/MERGE onto the SERVING fabric (`repro.serve.ServeCluster`).

    SPLIT: one degenerate ``(data=1, model=1)`` view per device — each an
    independent engine replica. MERGE: one fused ``(data=1, model=N)`` view
    — a single tensor-parallel engine spanning every device. These are the
    two topologies ``--cluster-mode`` chooses between in
    ``repro.launch.serve``.
    """
    devs = list(devices) if devices is not None else list(jax.devices())
    if Mode.parse(mode) is Mode.MERGE:
        return [serving_mesh_info(devs)]
    return [serving_mesh_info([d]) for d in devs]
