"""Training launcher: real steps on the available fabric.

On this CPU container it trains reduced configs end-to-end (the e2e example
drives a ~10-100M-param model for a few hundred steps); on a real cluster the
same entry point runs the full configs — the only difference is the mesh and
the ``--reduced`` flag.

Features wired here: SpatzformerCluster modes (MERGE by default — data
pipeline + async checkpointing ride the freed controller), rule-based
shardings, AdamW, checkpoint/restart, watchdog heartbeats.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch codeqwen1.5-7b \
      --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.ckpt import Checkpointer
from repro.configs import TrainConfig, get_arch
from repro.core import SpatzformerCluster
from repro.data import DataConfig, PrefetchLoader, SyntheticCorpus
from repro.dist.sharding import (
    MeshInfo,
    batch_shardings,
    param_shardings,
    replicated,
    single_device_mesh_info,
)
from repro.ft import Watchdog
from repro.models import LM
from repro.train import adamw_init, make_train_step


def build_mesh_info(args) -> MeshInfo:
    n = len(jax.devices())
    if n == 1:
        return single_device_mesh_info()
    cluster = SpatzformerCluster(n_pods=args.pods if n % args.pods == 0 else 1)
    if args.mode == "merge" and cluster.n_pods > 1:
        return cluster.merge_info()
    return cluster.pod_info(0)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--mode", default="merge", choices=["merge", "split"])
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TrainConfig(
        lr=args.lr,
        total_steps=args.steps,
        warmup_steps=max(args.steps // 20, 5),
        grad_accum=args.grad_accum,
        seed=args.seed,
    )
    info = build_mesh_info(args)
    model = LM(cfg, mesh_info=info if info.n_devices > 1 else None)
    print(f"arch={cfg.name} params={cfg.num_params():,} devices={info.n_devices}")

    # ---- state
    params = model.init(jax.random.key(args.seed))
    opt = adamw_init(params)
    p_shard = param_shardings(jax.eval_shape(lambda: params), info)
    o_shard = param_shardings(jax.eval_shape(lambda: opt), info)
    params = jax.device_put(params, p_shard)
    opt = jax.device_put(opt, o_shard)

    ckpt = Checkpointer(args.ckpt_dir, keep=3)
    start_step = 0
    if args.resume and ckpt.latest_step() is not None:
        (params, opt), start_step = ckpt.restore(
            jax.eval_shape(lambda: (params, opt)), shardings=(p_shard, o_shard)
        )
        print(f"resumed from step {start_step}")

    # ---- data (prefetch thread = scalar task on the freed controller)
    corpus = SyntheticCorpus(
        DataConfig(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    )
    loader = PrefetchLoader(corpus, start_step=start_step)

    # ---- step
    step_fn = make_train_step(model, tcfg)
    b_spec = batch_shardings(
        jax.eval_shape(lambda: corpus.batch(0)), info
    )
    m_shard = {k: replicated(info) for k in ("loss", "aux", "grad_norm", "lr")}
    jit_step = jax.jit(
        step_fn,
        in_shardings=(p_shard, o_shard, b_spec),
        out_shardings=(p_shard, o_shard, m_shard),
        donate_argnums=(0, 1),
    )

    wd = Watchdog(straggler_after=60.0, dead_after=600.0).start()
    wd.register("trainer")

    t0 = time.time()
    tok_per_step = args.batch * args.seq
    for step in range(start_step, args.steps):
        batch = next(loader)
        batch = jax.device_put(batch, b_spec)
        params, opt, metrics = jit_step(params, opt, batch)
        wd.beat("trainer", step)
        if (step + 1) % args.log_every == 0 or step == start_step:
            m = jax.tree.map(float, metrics)
            rate = tok_per_step * (step + 1 - start_step) / (time.time() - t0)
            print(
                f"step {step+1:5d} loss={m['loss']:.4f} gnorm={m['grad_norm']:.3f} "
                f"lr={m['lr']:.2e} tok/s={rate:,.0f}",
                flush=True,
            )
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt))

    ckpt.save(args.steps, (params, opt), blocking=True)
    loader.close()
    wd.stop()
    print(f"done in {time.time()-t0:.1f}s; final loss above. ckpts in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
