import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first — jax locks the device count at first
initialization, and the production meshes need 512 host placeholder devices.

For every live cell (repro.configs.all_cells) on the single-pod (16,16) and
multi-pod (2,16,16) meshes this script:

  1. builds the jitted step (train_step / forward / decode_step) with
     in/out shardings from the rule-based sharding layer,
  2. ``.lower()`` s it on ShapeDtypeStruct stand-ins (no allocation),
  3. ``.compile()`` s — sharding mismatches, unsupported collectives and
     compile-time OOMs all surface HERE,
  4. records ``memory_analysis()`` / ``cost_analysis()`` / the collectives
     parsed from the partitioned HLO, alongside the analytic roofline terms
     (repro.roofline) into a JSONL consumed by the benchmark tables
     (``benchmarks/make_experiments_tables.py``,
     ``benchmarks/roofline_bench.py``) and the perf loop.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out benchmarks/results/dryrun.jsonl
  python -m repro.launch.dryrun --arch mistral-large-123b --shape train_4k \
      --mesh single --hlo-dir /tmp/hlo
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, all_cells, get_arch, get_shape
from repro.dist.sharding import MeshInfo, batch_shardings, param_shardings, replicated
from repro.launch.mesh import make_production_mesh, mesh_info_for
from repro.models.model import LM, input_specs
from repro.roofline.analysis import RooflineTerms, parse_collectives
from repro.roofline.flops import count_cell
from repro.train.optimizer import adamw_init
from repro.train.step import make_train_step


def lower_cell(
    arch_name: str,
    shape_name: str,
    multi_pod: bool,
    *,
    baseline: bool = False,
):
    """Build + lower + compile one cell. Returns a result record dict.

    ``baseline=True`` disables the beyond-paper memory policies (grad-accum
    sizing, f8 KV, FSDP) — used by the before/after perf measurements.
    """
    import dataclasses

    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    info = mesh_info_for(mesh)
    rec: dict = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": mesh.size,
        "kind": shape.kind,
        "baseline": baseline,
    }

    # ---- memory policies (each one a recorded perf iteration) ----
    grad_accum = 1
    strategy = "tp"
    if not baseline:
        n_p = cfg.num_params()
        if shape.kind == "train":
            # microbatching where live activations demand it (SSM state
            # streams; ≥8B dense). Small dense models skip it — it buys
            # nothing there and accum=2 trips an SPMD partitioner edge on
            # minicpm3's replicated-vocab embedding grads.
            if n_p >= 100e9:
                grad_accum = 8
            elif n_p >= 50e9:
                grad_accum = 4
            elif n_p >= 8e9 or cfg.family in ("ssm", "hybrid"):
                grad_accum = 2
            # DP+ZeRO-1 for small non-MoE models: roofline shows TP-16
            # all-reduces of activation-sized payloads dominate (zamba2:
            # t_coll 2.05 s vs t_comp 0.29 s). Replicate weights, fold the
            # model axis into DP, shard optimizer state 256-way.
            if cfg.family != "moe" and 2 * n_p * 2 <= 13e9:
                strategy = "dp_zero1"
                info = MeshInfo(
                    mesh,
                    batch_axes=info.batch_axes + ("model",),
                    tp_enabled=False,
                )
            elif cfg.family != "moe" and n_p <= 16e9:
                # mid-size: weights can't replicate but CAN be ZeRO-3
                # sharded over the full fabric with per-layer gathers —
                # 3 param-AG passes cost less wire than L layers of
                # activation all-reduces (falcon-mamba: 10.7 vs 25 TB)
                strategy = "dp_zero3"
                info = MeshInfo(
                    mesh,
                    batch_axes=info.batch_axes + ("model",),
                    tp_enabled=False,
                )
        if shape.kind == "decode":
            # f8 KV storage when the bf16 cache would crowd HBM
            cache_elems = (
                shape.global_batch * shape.seq_len * cfg.n_layers
            ) * (
                (cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim)
                if cfg.mla is not None
                else 2 * cfg.n_kv_heads * cfg.head_dim
            )
            if cache_elems * 2 / mesh.size > 4e9:  # >4 GB/dev in bf16
                cfg = dataclasses.replace(cfg, kv_cache_dtype="float8_e4m3fn")
        if shape.kind == "prefill" and cfg.d_model >= 8192:
            # wide models: smaller KV chunk shrinks the [B,H,S,chunk] f32
            # online-softmax block
            cfg = dataclasses.replace(cfg, attn_chunk=256)
    rec["grad_accum"] = grad_accum
    rec["strategy"] = strategy
    rec["kv_cache_dtype"] = cfg.kv_cache_dtype or cfg.dtype
    model = LM(cfg, mesh_info=info)

    params_s = model.param_specs()
    # FSDP/ZeRO second-dim sharding when TP-only state won't fit HBM:
    # train keeps params(bf16)+grads(bf16)+AdamW moments(2×f32) resident.
    state_mult = 12 if shape.kind == "train" else 2
    per_dev = cfg.num_params() * state_mult / info.model_size
    fsdp = ((per_dev > 8e9) or strategy == "dp_zero3") and not baseline
    rec["fsdp"] = bool(fsdp)
    p_shard = param_shardings(params_s, info, fsdp=fsdp)
    opt_fsdp = fsdp or strategy in ("dp_zero1", "dp_zero3")
    batch_s = input_specs(cfg, shape)
    b_shard = batch_shardings(batch_s, info)

    t0 = time.time()
    if shape.kind == "train":
        tcfg = TrainConfig(grad_accum=grad_accum)
        step = make_train_step(model, tcfg)
        opt_s = jax.eval_shape(lambda: adamw_init(params_s))
        o_shard = param_shardings(opt_s, info, fsdp=opt_fsdp, fsdp_threshold=2**22)
        m_shard = {k: replicated(info) for k in ("loss", "aux", "grad_norm", "lr")}
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, m_shard),
                donate_argnums=(0, 1),
            ).lower(params_s, opt_s, batch_s)
    elif shape.kind == "prefill":
        with mesh:
            lowered = jax.jit(
                model.forward,
                in_shardings=(p_shard, b_shard),
            ).lower(params_s, batch_s)
    else:  # decode
        cache_s = model.cache_specs(shape.global_batch, shape.seq_len)
        c_shard = model.cache_shardings(cache_s, info)
        with mesh:
            lowered = jax.jit(
                model.decode_step,
                in_shardings=(p_shard, c_shard, b_shard, replicated(info)),
                out_shardings=(None, c_shard),
                donate_argnums=(1,),
            ).lower(
                params_s, cache_s, batch_s, jax.ShapeDtypeStruct((), jnp.int32)
            )
    rec["lower_s"] = round(time.time() - t0, 2)

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    ma = compiled.memory_analysis()
    rec["mem"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }
    # peak per-device ≈ args + temp (aliased args reuse their buffers)
    rec["mem"]["peak_bytes"] = int(
        ma.argument_size_in_bytes + ma.temp_size_in_bytes - ma.alias_size_in_bytes
    )
    # XLA *CPU* converts bf16 weights to f32 around dots (convert fusions),
    # holding a ~2×params f32 copy of the touched weight stacks in temp.
    # TPU executes bf16 natively on the MXU — no such copies. Report a
    # TPU-adjusted estimate alongside the raw number (evidence: temp has a
    # B/S-independent component ≈ 2× per-device param bytes in the dry-run
    # artifact).
    from repro.common.utils import pytree_bytes

    param_dev_bytes = pytree_bytes(params_s) / mesh.size * info.data_size
    if not fsdp:
        rec["mem"]["tpu_adjusted_peak_bytes"] = int(
            max(rec["mem"]["peak_bytes"] - 2 * param_dev_bytes, 0)
        )
    else:  # FSDP: weights are gathered per layer; the f32 copies are transient
        rec["mem"]["tpu_adjusted_peak_bytes"] = rec["mem"]["peak_bytes"]
    ca = compiled.cost_analysis() or {}
    rec["hlo_flops_raw"] = float(ca.get("flops", 0.0))
    rec["hlo_bytes_raw"] = float(ca.get("bytes accessed", 0.0))
    hlo_text = compiled.as_text()
    rec["collectives_raw"] = parse_collectives(hlo_text)
    from repro.roofline.hlo_loops import corrected_collectives

    # loop-corrected: while (scan) bodies multiplied by their trip counts —
    # the measured cross-check for the analytic collective term
    rec["collectives_corrected"] = corrected_collectives(hlo_text)

    # analytic roofline (global counts)
    dp = info.data_size
    tp = info.model_size
    zero = {"dp_zero1": "zero1", "dp_zero3": "zero3"}.get(strategy, "none")
    counts = count_cell(cfg, shape, dp=dp, tp=tp, zero=zero)
    terms = RooflineTerms(
        name=f"{arch_name}/{shape_name}/{rec['mesh']}",
        chips=mesh.size,
        flops=counts.flops,
        hbm_bytes=counts.hbm_bytes,
        coll_bytes=counts.coll_bytes,
        model_flops=counts.model_flops,
    )
    rec["analytic"] = {
        "flops": counts.flops,
        "hbm_bytes": counts.hbm_bytes,
        "coll_bytes": counts.coll_bytes,
        "model_flops": counts.model_flops,
        "t_compute": terms.t_compute,
        "t_memory": terms.t_memory,
        "t_collective": terms.t_collective,
        "bottleneck": terms.bottleneck,
        "step_time": terms.step_time,
        "mfu": terms.mfu,
        "usefulness": terms.usefulness,
    }
    rec["ok"] = True
    return rec, compiled, lowered


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results/dryrun.jsonl")
    ap.add_argument("--hlo-dir", default=None, help="dump compiled HLO text here")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    cells = all_cells()
    if args.arch != "all":
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape != "all":
        cells = [c for c in cells if c[1] == args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    with open(args.out, "w") as f:
        for arch_name, shape_name in cells:
            for multi_pod in meshes:
                tag = f"{arch_name}/{shape_name}/{'multi' if multi_pod else 'single'}"
                try:
                    rec, compiled, _ = lower_cell(arch_name, shape_name, multi_pod)
                    peak = rec["mem"]["peak_bytes"] / 1e9
                    an = rec["analytic"]
                    print(
                        f"OK   {tag:64s} compile={rec['compile_s']:7.1f}s "
                        f"peak/dev={peak:7.2f}GB bound={an['bottleneck']:10s} "
                        f"step={an['step_time']*1e3:8.2f}ms MFU={an['mfu']*100:5.1f}%",
                        flush=True,
                    )
                    if args.hlo_dir:
                        os.makedirs(args.hlo_dir, exist_ok=True)
                        with open(
                            os.path.join(args.hlo_dir, tag.replace("/", "__") + ".hlo"),
                            "w",
                        ) as hf:
                            hf.write(compiled.as_text())
                    del compiled
                except Exception as e:  # noqa: BLE001 - report and continue
                    rec = {
                        "arch": arch_name,
                        "shape": shape_name,
                        "mesh": "2x16x16" if multi_pod else "16x16",
                        "ok": False,
                        "error": f"{type(e).__name__}: {e}",
                    }
                    print(f"FAIL {tag}: {rec['error'][:200]}", flush=True)
                    if args.fail_fast:
                        traceback.print_exc()
                        raise
                f.write(json.dumps(rec) + "\n")
                f.flush()
                results.append(rec)

    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} cells compiled OK -> {args.out}")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
