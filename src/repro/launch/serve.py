"""Serving launcher: batched decode with the continuous-batching engine.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch codeqwen1.5-7b --reduced \
      --requests 16 --slots 4 --max-new 32
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import LM
from repro.serve import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = LM(cfg)
    params = model.init(jax.random.key(args.seed))
    engine = ServeEngine(
        model, params, batch_slots=args.slots, max_len=args.max_len, seed=args.seed
    )
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2 + 1, args.prompt_len + 1))
        engine.submit(
            Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
                max_new=args.max_new,
                temperature=args.temperature,
            )
        )
    stats = engine.run()
    lat = [
        (r.first_token_at - r.submitted_at, r.done_at - r.submitted_at)
        for r in engine.finished
    ]
    ttft = sum(l[0] for l in lat) / len(lat)
    e2e = sum(l[1] for l in lat) / len(lat)
    print(
        f"arch={cfg.name} requests={stats.total_requests} "
        f"decoded_tokens={stats.total_tokens} ticks={stats.ticks}\n"
        f"throughput={stats.tokens_per_sec:,.1f} tok/s  "
        f"mean TTFT={ttft*1e3:.1f}ms  mean e2e={e2e*1e3:.1f}ms"
    )


if __name__ == "__main__":
    main()
