"""Serving launcher: continuous batching on one engine or a reconfigurable
split/merge multi-device cluster.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch codeqwen1.5-7b --reduced \
      --requests 16 --slots 4 --max-new 32

  # multi-device (e.g. XLA_FLAGS=--xla_force_host_platform_device_count=2):
  ... -m repro.launch.serve --arch codeqwen1.5-7b --reduced --cluster-mode split

  # heterogeneous: different models pinned per split replica, requests
  # round-robined across them (the router dispatches by model name):
  ... -m repro.launch.serve --reduced --model chat=minicpm3-4b \
      --model bulk=falcon-mamba-7b --cluster-mode split

  # closed-loop: serve under a ReconfigController that switches split<->
  # merge mid-stream when the perfmodel-predicted win clears switch cost:
  ... -m repro.launch.serve --arch codeqwen1.5-7b --reduced --cluster-mode auto
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.modes import Mode
from repro.models import LM
from repro.serve import (
    AdmissionPolicy,
    AdmissionRejected,
    Request,
    SamplingParams,
    ServeCluster,
    ServeEngine,
    SpeculateConfig,
)


def _resolve_auto(n_devices: int, n_requests: int, slots: int) -> str:
    """``--cluster-mode auto`` on one device degenerates to a single
    engine; with several devices the STARTING mode matches the workload
    (many independent requests want split replicas, few large ones want
    the merged wide engine) and a ReconfigController owns every switch
    after that — auto serves through ``run_controlled``, the paper's
    closed control loop, not a one-shot static guess."""
    if n_devices <= 1:
        return "single"
    return "split" if n_requests >= 2 * slots else "merge"


def _parse_models(pairs: list[str], ap: argparse.ArgumentParser) -> dict[str, str]:
    """``--model name=arch`` pairs -> ordered {name: arch}; the first
    entry is the cluster's primary model (unpinned requests land there)."""
    out: dict[str, str] = {}
    for pair in pairs:
        name, sep, arch = pair.partition("=")
        if not sep or not name or not arch:
            ap.error(f"--model wants NAME=ARCH, got {pair!r}")
        if name in out:
            ap.error(f"--model names {name!r} twice")
        out[name] = arch
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument(
        "--model", action="append", default=None, metavar="NAME=ARCH",
        help="heterogeneous serving: repeat to pin several named models "
        "onto one split cluster (one model per replica, cost-weighted "
        "placement); requests round-robin across the names and the router "
        "dispatches each to its model's replicas. Mutually exclusive with "
        "--arch; needs a split-capable cluster mode",
    )
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    # per-request sampling configuration (one SamplingParams for the whole
    # synthetic stream; a real deployment would vary these per request)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0, help="0 disables")
    ap.add_argument("--top-p", type=float, default=1.0, help="1.0 disables")
    ap.add_argument(
        "--sample-seed", type=int, default=None,
        help="per-request PRNG seed base (request i uses seed+i); default: "
        "engine-assigned",
    )
    ap.add_argument(
        "--stop", type=int, nargs="*", default=(),
        help="stop token id(s): streams terminate at (and include) the first hit",
    )
    ap.add_argument(
        "--stream", action="store_true",
        help="stream request 0's tokens incrementally through its "
        "RequestHandle (the other requests decode alongside), then drain",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--cluster-mode", choices=("single", "split", "merge", "auto"),
        default="single",
        help="single: one engine on the default device; split: one engine "
        "replica per device behind the JSQ router; merge: one tensor-"
        "parallel engine over every device; auto: pick by workload shape",
    )
    eng_sel = ap.add_mutually_exclusive_group()
    eng_sel.add_argument(
        "--unified", dest="unified", action="store_true", default=None,
        help="force the unified ragged prefill+decode dispatch",
    )
    eng_sel.add_argument(
        "--legacy", dest="unified", action="store_false",
        help="force the legacy synchronous-prefill engine",
    )
    ap.add_argument(
        "--no-prewarm", action="store_true",
        help="skip prewarm(): compiles land inside the timed region",
    )
    ap.add_argument(
        "--kv-block-size", type=int, default=None,
        help="enable block-paged KV serving with this block size (tokens "
        "per block; must divide --max-len). Default: dense per-slot cache",
    )
    ap.add_argument(
        "--num-blocks", type=int, default=None,
        help="KV pool size in blocks (paged mode). Default: byte parity "
        "with the dense cache (slots * max_len / block_size); smaller "
        "pools oversubscribe and make admission wait on pool pressure",
    )
    ap.add_argument(
        "--prefix-cache", action="store_true",
        help="radix prefix reuse across requests (requires --kv-block-size)",
    )
    ap.add_argument(
        "--speculate", default="off",
        help="speculative decoding: 'off' (default), 'ngram' (prompt-lookup "
        "drafter, zero extra weights), 'draft' (1-layer truncated-self "
        "draft model) or 'draft:<arch>' (separate draft architecture). "
        "Output is bit-identical to --speculate off for seeded requests",
    )
    ap.add_argument(
        "--spec-k", type=int, default=None,
        help="max speculation depth (proposed tokens per slot per verify "
        "dispatch); default 8, adaptively shrunk per slot by acceptance",
    )
    ap.add_argument(
        "--kv-dtype", choices=("f32", "int8", "fp8"), default="f32",
        help="KV cache storage dtype: f32 (default, byte-identical to "
        "before the flag existed), int8, or fp8 (float8_e4m3fn) — rows "
        "quantized at insert time with per-(position, head) f32 scales, "
        "dequantized inside the attention kernels. Both narrow lanes are "
        "~3-4x fewer resident KV bytes per position; fp8 trades int8's "
        "peak accuracy for dynamic range on small elements",
    )
    ap.add_argument(
        "--weight-dtype", choices=("f32", "int8"), default="f32",
        help="matmul weight storage dtype: f32 (default) or int8 with "
        "per-output-channel scales (routers/norms/embeddings stay f32)",
    )
    ap.add_argument(
        "--max-queue", type=int, default=None,
        help="admission control (cluster modes): bound each replica's wait "
        "queue; arrivals beyond it are rejected 'queue_full' instead of "
        "growing TTFT without bound",
    )
    ap.add_argument(
        "--deadline-s", type=float, default=None,
        help="admission control (cluster modes): per-request TTFT deadline; "
        "arrivals whose predicted TTFT exceeds it are shed up front "
        "('shed_deadline') rather than served hopelessly late",
    )
    args = ap.parse_args()
    if args.prefix_cache and not args.kv_block_size:
        ap.error("--prefix-cache requires --kv-block-size")
    if (args.arch is None) == (args.model is None):
        ap.error("pass exactly one of --arch or --model NAME=ARCH")
    admission_on = args.max_queue is not None or args.deadline_s is not None
    if admission_on and args.cluster_mode == "single":
        ap.error("--max-queue/--deadline-s need a cluster mode (admission "
                 "control lives at the cluster layer)")

    def build(arch: str, seed: int):
        cfg = get_arch(arch)
        if args.reduced:
            cfg = cfg.reduced()
        model = LM(cfg)
        return cfg, model, model.init(jax.random.key(seed))

    named = None  # {name: (cfg, LM, params)} when --model pairs were given
    if args.model is not None:
        named = {
            name: build(arch, args.seed + i)
            for i, (name, arch) in enumerate(
                _parse_models(args.model, ap).items()
            )
        }
        cfg, model, params = next(iter(named.values()))  # primary model
    else:
        cfg, model, params = build(args.arch, args.seed)

    hetero = named is not None and len(named) > 1
    controlled = False  # auto: serve under a ReconfigController
    mode = args.cluster_mode
    if mode == "auto":
        mode = _resolve_auto(len(jax.devices()), args.requests, args.slots)
        controlled = mode != "single" and not hetero  # hetero stays split
        if hetero:
            mode = "split"
        print(f"cluster-mode auto -> {mode}"
              + (" (closed-loop run_controlled)" if controlled else ""))
    if hetero and mode != "split":
        ap.error("--model with several names is split-only (one model per "
                 "replica; merge cannot fuse different parameterizations)")
    if admission_on and mode == "single":
        ap.error("--max-queue/--deadline-s need a cluster mode (admission "
                 "control lives at the cluster layer)")
    spec_kw = {} if args.spec_k is None else {"k": args.spec_k}
    speculate = SpeculateConfig.parse(args.speculate, **spec_kw)
    common = dict(
        batch_slots=args.slots, max_len=args.max_len, seed=args.seed,
        unified=args.unified, kv_block_size=args.kv_block_size,
        num_blocks=args.num_blocks, prefix_cache=args.prefix_cache,
        speculate=speculate,
        # the f32 default maps to None: the engine's plain (scale-less)
        # path, byte-identical to a launcher without these flags
        kv_dtype=None if args.kv_dtype == "f32" else args.kv_dtype,
        weight_dtype=None if args.weight_dtype == "f32" else args.weight_dtype,
    )
    if mode == "single":
        target = ServeEngine(model, params, **common)
        desc = "single-device engine"
    else:
        if admission_on:
            common["admission"] = AdmissionPolicy(max_queue=args.max_queue)
        if named is not None:
            target = ServeCluster(
                models={n: (m, p) for n, (_, m, p) in named.items()},
                mode=Mode.parse(mode), **common,
            )
            plan = target.replica_plan()
            if plan is not None:
                print("placement: " + "  ".join(
                    f"{n}->replicas{ix}" for n, ix in plan.items()
                ))
        else:
            target = ServeCluster(model, params, mode=Mode.parse(mode), **common)
        desc = f"{target!r}"

    # production serving compiles once, then serves: every dispatch variant
    # — including the fused top-k/top-p sampler variants if any request will
    # need them — is built BEFORE the timed region unless explicitly disabled
    sampling = args.temperature > 0 or args.top_k > 0 or args.top_p < 1.0
    if not args.no_prewarm:
        target.prewarm(sampling=sampling)

    rng = np.random.default_rng(args.seed)
    names = list(named) if named is not None else [None]
    handles = []
    for i in range(args.requests):
        # heterogeneous streams round-robin across the pinned models; each
        # request samples its prompt from ITS model's vocabulary
        name = names[i % len(names)]
        req_cfg = cfg if name is None else named[name][0]
        plen = int(rng.integers(args.prompt_len // 2 + 1, args.prompt_len + 1))
        req = (
            Request(
                rid=i,
                prompt=rng.integers(0, req_cfg.vocab_size, size=plen).astype(np.int32),
                model=name,
                params=SamplingParams(
                    max_new=args.max_new,
                    temperature=args.temperature,
                    top_k=args.top_k,
                    top_p=args.top_p,
                    seed=None if args.sample_seed is None else args.sample_seed + i,
                    stop=tuple(args.stop),
                ),
                deadline_s=args.deadline_s,
            )
        )
        try:
            handles.append(target.submit(req))
        except AdmissionRejected as rej:
            print(f"req {i} rejected at admission: {rej}")
    if args.stream and handles:
        # the handle iterator drives the engine; every other request makes
        # progress in the same ticks — streaming is a view, not a mode
        print("req 0 stream: ", end="", flush=True)
        for tok in handles[0]:
            print(tok, end=" ", flush=True)
        print(f"[{handles[0].finish_reason}]")
    if controlled:
        # auto: the closed loop — interval slicing, window observation,
        # controller-committed split<->merge switches, measured costs
        stats = target.run_controlled()
        for rep in stats.reconfigures:
            print(f"controller switch: {rep}")
    else:
        stats = target.run()
    # in --stream mode part (or all) of the work was served by the handle-
    # driven pump BEFORE run(), so report totals from the request objects
    # and keep the timed-drain stats for throughput/latency
    done = list(target.finished)
    n_cancelled = sum(r.finish_reason == "cancelled" for r in done)
    arch_label = (
        cfg.name if named is None
        else "+".join(f"{n}:{c.name}" for n, (c, _, _) in named.items())
    )
    if named is not None:
        per_model = {n: 0 for n in named}
        for r in done:
            if r.model in per_model:
                per_model[r.model] += len(r.generated)
        print("per-model tokens: " + "  ".join(
            f"{n}={t}" for n, t in per_model.items()
        ))
    print(
        f"arch={arch_label} [{desc}] requests={len(done) - n_cancelled} "
        f"(+{n_cancelled} cancelled) "
        f"generated_tokens={sum(len(r.generated) for r in done)}\n"
        f"drain: {stats.total_tokens} decode tokens, {stats.ticks} ticks, "
        f"throughput={stats.tokens_per_sec:,.1f} tok/s  "
        f"TTFT p50={stats.ttft_p50*1e3:.1f}ms p99={stats.ttft_p99*1e3:.1f}ms  "
        f"TPOT p50={stats.tpot_p50*1e3:.2f}ms p99={stats.tpot_p99*1e3:.2f}ms"
    )
    # backpressure / robustness counters: queue high-water mark and KV-pool
    # admission failures come from the engine(s); shed/rejected/rehomed only
    # move when the cluster's admission controller or failure recovery acted
    bp = (
        f"backpressure: queue_peak={getattr(stats, 'queue_peak', 0)} "
        f"alloc_failures={getattr(stats, 'alloc_failures', 0)}"
    )
    if mode != "single":
        # lifetime totals from the admission controller (run()'s stats deltas
        # start at run() entry and would miss this launcher's submit-time
        # rejections)
        adm = target.admission
        bp += (
            f" shed={0 if adm is None else adm.shed}"
            f" rejected={0 if adm is None else adm.rejected}"
            f" rehomed={getattr(stats, 'rehomed', 0)}"
        )
    print(bp)
    # dtype-aware capacity report: actual resident KV bytes (peak over the
    # run), never slots x max_len x f32 — an int8 cache really is ~3-4x
    # lighter per position and this is where that shows up
    print(
        f"kv: dtype={args.kv_dtype} weights={args.weight_dtype} "
        f"resident_bytes={getattr(stats, 'kv_bytes_resident', 0):,}"
    )
    if speculate is not None:
        print(
            f"speculate[{speculate.mode}]: "
            f"accepted {stats.spec_accepted}/{stats.spec_proposed} drafts "
            f"({stats.spec_acceptance:.0%}) over "
            f"{stats.spec_ticks} verify dispatches"
        )
    if args.kv_block_size:
        engines = [target] if mode == "single" else target.engines
        for i, e in enumerate(engines):
            line = f"paged[{i}]: {e.pool.stats()}"
            if e.prefix is not None:
                line += f"\n          {e.prefix.stats()}"
            print(line)


if __name__ == "__main__":
    main()
