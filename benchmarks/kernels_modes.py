"""Paper Fig. 2 (left): the six vector kernels under baseline / SM / MM.

Modeled on the v5e fabric (512 chips = 2 pods × 256): per kernel we report
  * baseline  — non-reconfigurable dual-pod: data-split across pods, one
    program per pod per kernel + host barrier (the Spatz-cluster baseline),
  * SM        — Spatzformer split mode: identical schedule to baseline (the
    paper's C3 parity claim — reconfigurability adds no per-kernel cost;
    the mode indirection is host-side and measured in reconfig_cost.py),
  * MM        — merge mode: ONE fused program on 512 chips (single dispatch,
    on-device cross-pod collectives),
plus modeled energy (paper's right-hand energy-efficiency bars).

FFT additionally runs the STAGED sync-bound variant (rounds of
phase→exchange→phase), where MM's advantage is the paper's +20% story.
Measured-on-CPU mechanism timings (1-core container: no fabric scaling,
reported for the dispatch-path reality check only) come from common.py.
"""

from __future__ import annotations

import argparse
import json
import os

from repro.core.perfmodel import (
    V5E,
    KernelCost,
    model_staged_merge,
    model_staged_split,
    model_vector_stream,
)

from benchmarks.common import PAPER_KERNELS, measured_kernels, time_thunk

CHIPS_PER_POD = 256
PODS = 2


def run(csv: bool = True, tiny: bool = False) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    meas = measured_kernels(scale=64 if tiny else 256)
    for name, cost in PAPER_KERNELS.items():
        # baseline / SM: each pod runs half the data; barrier at the end.
        half = KernelCost(name, cost.flops / PODS, cost.hbm_bytes / PODS, cost.coll_bytes)
        t_pod, e_pod = model_vector_stream([half], CHIPS_PER_POD)
        t_sm = t_pod + V5E.barrier_overhead
        e_sm = e_pod * PODS
        # MM: one fused program over all chips.
        t_mm, e_mm = model_vector_stream([cost], CHIPS_PER_POD * PODS)
        t_meas = time_thunk(meas[name])
        rows.append((f"kernel_{name}_baseline_ms", t_sm * 1e3, "modeled v5e, 2 pods"))
        rows.append((f"kernel_{name}_SM_ms", t_sm * 1e3, "≡ baseline (C3 parity)"))
        rows.append((f"kernel_{name}_MM_ms", t_mm * 1e3, f"speedup={t_sm/t_mm:.3f}x"))
        rows.append(
            (f"kernel_{name}_MM_energy_rel", e_mm / e_sm, "MM energy / SM energy")
        )
        rows.append((f"kernel_{name}_cpu_measured_us", t_meas * 1e6, "1-core mechanism check"))

    # --- staged sync-bound FFT (the +20% claim) ---
    n_rows, n_pts = 65536, 16384
    phase = KernelCost(
        "fft_phase",
        flops=PAPER_KERNELS["fft"].flops / 2,
        hbm_bytes=PAPER_KERNELS["fft"].hbm_bytes / 2,
    )
    xbytes = n_rows * n_pts * 8  # complex64 corner turn
    for rounds in (1, 2, 4, 8):
        sm = model_staged_split(phase, rounds, xbytes, CHIPS_PER_POD, PODS)
        mm = model_staged_merge(phase, rounds, xbytes, CHIPS_PER_POD * PODS)
        rows.append(
            (
                f"fft_staged_r{rounds}_MM_speedup",
                sm.makespan / mm.makespan,
                f"SM={sm.makespan*1e3:.2f}ms MM={mm.makespan*1e3:.2f}ms "
                f"launches {sm.launches}->{mm.launches}",
            )
        )
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.6g},{d}")
    return rows


def main() -> None:
    """CLI entry point (the CI bench-smoke job): CSV to stdout, optional JSON
    artifact with enough metadata to line up BENCH_* trajectories across
    commits."""
    import jax

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--tiny", action="store_true", help="small measured kernels (CI smoke)"
    )
    ap.add_argument("--json", default=None, metavar="PATH", help="write rows as JSON")
    args = ap.parse_args()

    rows = run(csv=True, tiny=args.tiny)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        payload = {
            "benchmark": "kernels_modes",
            "tiny": bool(args.tiny),
            "devices": jax.device_count(),
            "jax": jax.__version__,
            "rows": [{"name": n, "value": v, "note": d} for n, v, d in rows],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {len(rows)} rows -> {args.json}")


if __name__ == "__main__":
    main()
