"""Serving benchmark: real continuous-batching engine throughput on this
host (reduced arch) + modeled production decode throughput per arch from the
dry-run decode cells (tokens/s/chip at the roofline step time).

The measured section reports STEADY-STATE serving throughput: a small
warmup drain first absorbs the one-time jit compiles (production serving
compiles once and then serves millions of tokens), then a ragged-length
request stream is timed end to end — decode ticks, admissions, prefills
and sampling included. Ragged prompt lengths are deliberate: they exercise
the prefill-bucketing path (without it, every distinct length is a fresh
XLA compile in the measured region).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import LM
from repro.serve import Request, ServeEngine

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.jsonl")

MEASURED_REQUESTS = 24
MAX_NEW = 12
PROMPT_LENS = (5, 8, 11, 13, 16, 19, 23, 27, 31, 34, 38, 43)  # ragged stream
# warmup must cycle EVERY prompt length so all prefill buckets compile
# before the measured region (otherwise rep 1 is compile-polluted)
WARMUP_REQUESTS = len(PROMPT_LENS)


def run(csv: bool = True) -> list[tuple[str, float, str]]:
    rows = []

    # ---- measured: the real engine on this host, reduced arch
    cfg = get_arch("codeqwen1.5-7b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, batch_slots=4, max_len=96)
    rng = np.random.default_rng(0)

    def submit(n: int, rid0: int) -> None:
        for i in range(n):
            s = PROMPT_LENS[i % len(PROMPT_LENS)]
            eng.submit(
                Request(
                    rid=rid0 + i,
                    prompt=rng.integers(0, cfg.vocab_size, size=s).astype(np.int32),
                    max_new=MAX_NEW,
                )
            )

    submit(WARMUP_REQUESTS, rid0=-WARMUP_REQUESTS)  # absorb jit compiles
    warm = eng.run()
    # best-of-3 measured drains: steady-state throughput, shared-host-noise
    # resistant (same reasoning as time_thunk's best-of-5)
    best = None
    for rep in range(3):
        submit(MEASURED_REQUESTS, rid0=rep * MEASURED_REQUESTS)
        stats = eng.run()
        if best is None or stats.tokens_per_sec > best.tokens_per_sec:
            best = stats
    rows.append(
        (
            "serve_engine_cpu_tok_per_s",
            best.tokens_per_sec,
            f"{best.total_requests} reqs, {best.ticks} ticks, "
            f"{best.prefill_compiles} prefill compiles in measured region, "
            "4 slots (1-core host, steady-state, best of 3)",
        )
    )
    rows.append(
        (
            # '_wall' suffix keeps this row OUT of the regression gate: jit
            # compile time is too machine-noisy for a ±20% wall-clock check
            "serve_engine_cold_start_wall",
            warm.wall_seconds,
            f"warmup drain incl. jit compiles ({warm.prefill_compiles} prefills)",
        )
    )

    # ---- modeled: production decode throughput from the dry-run cells
    if os.path.exists(RESULTS):
        for line in open(RESULTS):
            r = json.loads(line)
            if not r.get("ok") or r["kind"] != "decode" or r["mesh"] != "16x16":
                continue
            a = r["analytic"]
            batch = {"decode_32k": 128, "long_500k": 1}[r["shape"]]
            tps = batch / a["step_time"]
            rows.append(
                (
                    f"serve_modeled_{r['arch']}_{r['shape']}_tok_per_s",
                    tps,
                    f"step={a['step_time']*1e3:.2f}ms bound={a['bottleneck']} "
                    f"(256 chips, {tps/256:.1f} tok/s/chip)",
                )
            )
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.6g},{d}")
    return rows


def main() -> None:
    """CLI entry point (the CI bench-smoke job): CSV to stdout, optional JSON
    artifact comparable across commits via benchmarks.check_regression."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH", help="write rows as JSON")
    args = ap.parse_args()

    rows = run(csv=True)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        payload = {
            "benchmark": "serving",
            "devices": jax.device_count(),
            "jax": jax.__version__,
            "rows": [{"name": n, "value": v, "note": d} for n, v, d in rows],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {len(rows)} rows -> {args.json}")


if __name__ == "__main__":
    main()
