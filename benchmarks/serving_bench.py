"""Serving benchmark: real continuous-batching engine throughput on this
host (reduced arch) + modeled production decode throughput per arch from the
dry-run decode cells (tokens/s/chip at the roofline step time)."""

from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import LM
from repro.serve import Request, ServeEngine

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.jsonl")


def run(csv: bool = True) -> list[tuple[str, float, str]]:
    rows = []

    # ---- measured: the real engine on this host, reduced arch
    cfg = get_arch("codeqwen1.5-7b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, batch_slots=4, max_len=96)
    rng = np.random.default_rng(0)
    for i in range(8):
        eng.submit(
            Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
                max_new=12,
            )
        )
    stats = eng.run()
    rows.append(
        (
            "serve_engine_cpu_tok_per_s",
            stats.tokens_per_sec,
            f"{stats.total_requests} reqs, {stats.ticks} ticks, 4 slots (1-core host)",
        )
    )

    # ---- modeled: production decode throughput from the dry-run cells
    if os.path.exists(RESULTS):
        for line in open(RESULTS):
            r = json.loads(line)
            if not r.get("ok") or r["kind"] != "decode" or r["mesh"] != "16x16":
                continue
            a = r["analytic"]
            batch = {"decode_32k": 128, "long_500k": 1}[r["shape"]]
            tps = batch / a["step_time"]
            rows.append(
                (
                    f"serve_modeled_{r['arch']}_{r['shape']}_tok_per_s",
                    tps,
                    f"step={a['step_time']*1e3:.2f}ms bound={a['bottleneck']} "
                    f"(256 chips, {tps/256:.1f} tok/s/chip)",
                )
            )
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.6g},{d}")
    return rows


if __name__ == "__main__":
    run()
