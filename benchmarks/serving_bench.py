"""Serving benchmark: real continuous-batching engine throughput on this
host (reduced arch) + modeled production decode throughput per arch from the
dry-run decode cells (tokens/s/chip at the roofline step time).

Two measured scenarios:

* **steady-state drain** — a small warmup drain absorbs the one-time jit
  compiles (production serving compiles once and then serves millions of
  tokens), then a pre-submitted ragged-length request stream is timed end
  to end — decode ticks, admissions, prefills and sampling included.
  Ragged prompt lengths are deliberate: they exercise the packed T-bucket
  path (and the legacy prefill-bucketing path).
* **mixed-arrival stream** — an open-loop timed arrival schedule (bursty
  exponential inter-arrivals) drives BOTH engines over the identical
  stream: the legacy engine (synchronous B=1 prefill per admission — every
  admission stalls every decode slot) vs the unified ragged dispatch
  (decode tokens and prefill chunks packed into one kernel per tick).
  Reports tok/s and TTFT/TPOT p50/p99 per engine plus the unified/legacy
  speedup — the serving analogue of the paper's merge-mode win on mixed
  scalar-vector workloads.
* **speculative decoding** (``--spec-json``) — draft-and-verify (n-gram
  prompt lookup and the 1-layer truncated-self draft model) on a seeded
  low-temperature continuation stream vs the IDENTICAL stream with
  speculation off; reports acceptance, tok/s per drafter and the
  off→ngram speedup. Outputs are bit-identical by construction, so the
  rows measure pure scheduling/dispatch win. Report-only trajectory rows.
* **overload survival** (``--overload-json``) — an arrival burst far beyond
  capacity served ungated (TTFT grows with queue position) vs gated by the
  cluster's admission controller with per-request TTFT deadlines under the
  closed control loop: excess load is shed up front and the admitted
  remainder's p99 TTFT is held near the uncongested floor. Report-only
  trajectory rows.
* **cluster split-vs-merge** (``--cluster``, needs ≥ 2 devices) — the SAME
  mixed scalar-vector arrival stream served by ``ServeCluster`` in split
  mode (independent replicas behind the JSQ router) and merge mode (one
  tensor-parallel engine), plus the measured ``reconfigure()`` cost — the
  paper's CSR-write number — cold (first placement) and warm (cached
  fabric). Report-only trajectory rows.
* **heterogeneous cluster** (``--hetero-json``) — a mixed tenant stream
  (chat tenants pinned to a dense+MLA model, bulk tenants to a
  constant-memory SSM model) over a split cluster with one model per
  replica, dispatched by the model-aware router. Reports per-model TTFT,
  total throughput, and the SSM replica's constant state bytes against
  the attention replica's KV cache. Report-only trajectory rows.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.modes import Mode
from repro.models import LM
from repro.serve import (
    Request, SamplingParams, ServeCluster, ServeEngine, SpeculateConfig,
)

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.jsonl")

MEASURED_REQUESTS = 24
MAX_NEW = 12
PROMPT_LENS = (5, 8, 11, 13, 16, 19, 23, 27, 31, 34, 38, 43)  # ragged stream
# warmup must cycle EVERY prompt length so all prefill buckets compile
# before the measured region (otherwise rep 1 is compile-polluted)
WARMUP_REQUESTS = len(PROMPT_LENS)

# mixed-arrival scenario: oversubscribed open-loop stream (queueing and
# admission/decode interference dominate — the regime the unified dispatch
# exists for). Prompts are long relative to max_new, as in real serving.
# The head-to-head pair runs the host-sensible unified config (budget ≥
# every prompt → all admissions take the fused dense tier, the right call
# on a CPU-oracle host); a third engine with a TIGHT budget then pushes
# most prompts through the ragged chunked-pack tier so a regression in
# the packed path is visible and gated on its own rows.
MIXED_REQUESTS = 32
MIXED_MAX_NEW = 8
MIXED_PROMPT_RANGE = (12, 89)
MIXED_BUDGET = 96  # == max_len: whole prompts fused (CPU-favored tier)
MIXED_CHUNK_BUDGET = 32  # forces ≥33-token prompts through ragged packs
MIXED_MEAN_IAT_S = 0.003  # bursty: far below the per-request service time


def _model():
    cfg = get_arch("codeqwen1.5-7b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def run(csv: bool = True) -> list[tuple[str, float, str]]:
    rows = []

    # ---- measured: the real engine on this host, reduced arch
    cfg, model, params = _model()
    eng = ServeEngine(model, params, batch_slots=4, max_len=96)
    rng = np.random.default_rng(0)

    def submit(n: int, rid0: int) -> None:
        for i in range(n):
            s = PROMPT_LENS[i % len(PROMPT_LENS)]
            eng.submit(
                Request(
                    rid=rid0 + i,
                    prompt=rng.integers(0, cfg.vocab_size, size=s).astype(np.int32),
                    params=SamplingParams(max_new=MAX_NEW),
                )
            )

    submit(WARMUP_REQUESTS, rid0=-WARMUP_REQUESTS)  # absorb jit compiles
    warm = eng.run()
    # best-of-3 measured drains: steady-state throughput, shared-host-noise
    # resistant (same reasoning as time_thunk's best-of-5)
    best = None
    for rep in range(3):
        submit(MEASURED_REQUESTS, rid0=rep * MEASURED_REQUESTS)
        stats = eng.run()
        if best is None or stats.tokens_per_sec > best.tokens_per_sec:
            best = stats
    rows.append(
        (
            "serve_engine_cpu_tok_per_s",
            best.tokens_per_sec,
            f"{best.total_requests} reqs, {best.ticks} ticks, "
            f"{best.prefill_compiles} prefill compiles in measured region, "
            "4 slots (1-core host, steady-state, best of 3)",
        )
    )
    rows.append(
        (
            # recording-host-gated latency rows ('serve_engine' prefix):
            # only compared against a baseline from the same machine
            "serve_engine_ttft_p99_s",
            best.ttft_p99,
            "steady-state drain TTFT p99 (pre-submitted stream: includes queueing)",
        )
    )
    rows.append(
        (
            "serve_engine_tpot_p50_s",
            best.tpot_p50,
            "steady-state drain per-request mean inter-token time, p50",
        )
    )
    rows.append(
        (
            # '_wall' suffix keeps this row OUT of the regression gate: jit
            # compile time is too machine-noisy for a ±20% wall-clock check
            "serve_engine_cold_start_wall",
            warm.wall_seconds,
            f"warmup drain incl. jit compiles ({warm.prefill_compiles} prefills)",
        )
    )

    # ---- modeled: production decode throughput from the dry-run cells
    if os.path.exists(RESULTS):
        for line in open(RESULTS):
            r = json.loads(line)
            if not r.get("ok") or r["kind"] != "decode" or r["mesh"] != "16x16":
                continue
            a = r["analytic"]
            batch = {"decode_32k": 128, "long_500k": 1}[r["shape"]]
            tps = batch / a["step_time"]
            rows.append(
                (
                    f"serve_modeled_{r['arch']}_{r['shape']}_tok_per_s",
                    tps,
                    f"step={a['step_time']*1e3:.2f}ms bound={a['bottleneck']} "
                    f"(256 chips, {tps/256:.1f} tok/s/chip)",
                )
            )
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.6g},{d}")
    return rows


# sampled-decode scenario: the SAME steady-state drain shape as the gated
# all-greedy row, but every request streams through the device-side fused
# sampler (temperature + nucleus top-p, per-request seeds). Report-only
# trajectory rows ("_sampled_" in check_regression): they track what the
# masked renormalized sampler costs per PR, while the gate proper is the
# UNCHANGED all-greedy row — the redesign's C3 parity claim is that smode 0
# still skips threefry/bias/sort entirely.
SAMPLED_TOP_P = 0.9
SAMPLED_TEMP = 0.8


def run_sampled(csv: bool = True) -> list[tuple[str, float, str]]:
    """Steady-state drain with top-p sampling on every request."""
    cfg, model, params = _model()
    eng = ServeEngine(model, params, batch_slots=4, max_len=96)
    # every sampler variant compiles off the timed path, like production
    eng.prewarm(sampling=True)
    rng = np.random.default_rng(0)

    def submit(n: int, rid0: int) -> None:
        for i in range(n):
            s = PROMPT_LENS[i % len(PROMPT_LENS)]
            eng.submit(
                Request(
                    rid=rid0 + i,
                    prompt=rng.integers(0, cfg.vocab_size, size=s).astype(np.int32),
                    params=SamplingParams(
                        max_new=MAX_NEW, temperature=SAMPLED_TEMP,
                        top_p=SAMPLED_TOP_P, seed=rid0 + i,
                    ),
                )
            )

    submit(WARMUP_REQUESTS, rid0=-WARMUP_REQUESTS)
    eng.run()
    best = None
    for rep in range(3):
        submit(MEASURED_REQUESTS, rid0=rep * MEASURED_REQUESTS)
        stats = eng.run()
        if best is None or stats.tokens_per_sec > best.tokens_per_sec:
            best = stats
    rows = [
        (
            "serve_engine_sampled_topp_tok_per_s",
            best.tokens_per_sec,
            f"{best.total_requests} reqs, top_p={SAMPLED_TOP_P} "
            f"temp={SAMPLED_TEMP} fused device sampler "
            "(steady-state drain, best of 3; report-only trajectory row)",
        ),
        (
            "serve_engine_sampled_topp_tpot_p50_s",
            best.tpot_p50,
            "sampled-decode mean inter-token time, p50 (report-only)",
        ),
    ]
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.6g},{d}")
    return rows


def _mixed_stream(cfg, seed: int = 42):
    """One deterministic arrival schedule; fresh Request objects per call
    (the engine mutates them)."""
    arr = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(MIXED_REQUESTS):
        t += float(arr.exponential(MIXED_MEAN_IAT_S))
        s = int(arr.integers(*MIXED_PROMPT_RANGE))
        out.append(
            (
                t,
                Request(
                    rid=i,
                    prompt=arr.integers(0, cfg.vocab_size, size=s).astype(np.int32),
                    params=SamplingParams(max_new=MIXED_MAX_NEW),
                ),
            )
        )
    return out


def run_mixed(csv: bool = True) -> list[tuple[str, float, str]]:
    """Mixed-arrival head-to-head: legacy vs unified on the same stream."""
    cfg, model, params = _model()
    rows = []
    stats_by = {}
    configs = (
        ("legacy", False, MIXED_BUDGET),
        ("unified", True, MIXED_BUDGET),
        # chunked-tier coverage: most prompts stream through ragged packs
        ("chunked", True, MIXED_CHUNK_BUDGET),
    )
    for name, unified, budget in configs:
        eng = ServeEngine(
            model, params, batch_slots=4, max_len=96,
            unified=unified, prefill_budget=budget,
        )
        # prewarm + warmup drain cover every dispatch variant and prefill
        # bucket this engine can hit, so the timed region measures serving,
        # not XLA (one compile inside a live arrival stream stalls every
        # queued request's TTFT). The warmup must include a > budget prompt
        # so the ragged chunked tier's buckets are warm too.
        eng.prewarm()
        rng = np.random.default_rng(1)
        for i, s in enumerate(
            np.linspace(*MIXED_PROMPT_RANGE, 12).astype(int)
        ):
            eng.submit(
                Request(
                    rid=-1 - i,
                    prompt=rng.integers(0, cfg.vocab_size, size=int(s)).astype(
                        np.int32
                    ),
                    params=SamplingParams(max_new=MIXED_MAX_NEW),
                )
            )
        eng.run()
        # best-of-2 by throughput (all latency rows from the same run, for
        # self-consistency): single-shot arrival streams are too noisy on a
        # shared 2-vCPU host to commit as a ±20% gate baseline
        stats = None
        for _ in range(2):
            s = eng.run(arrivals=_mixed_stream(cfg))
            if stats is None or s.tokens_per_sec > stats.tokens_per_sec:
                stats = s
        stats_by[name] = stats
        note = (
            f"{stats.total_requests} reqs, {stats.ticks} ticks, "
            f"{stats.prefill_compiles} compiles in timed region"
        )
        if name == "chunked":
            # the chunked config exists to make the ragged pack path's
            # throughput VISIBLE in the per-PR artifact trajectory (like
            # every *_mixed_* row it is report-only — open-loop scenarios
            # are too run-volatile for the ±20% gate); its latency profile
            # is additionally a config artifact (a tight budget stretches
            # admissions by design), so only tok/s is emitted
            rows.append(
                (
                    f"serve_engine_mixed_{name}_tok_per_s",
                    stats.tokens_per_sec,
                    note + f" (ragged packed-prefill tier, budget {MIXED_CHUNK_BUDGET})",
                )
            )
            continue
        rows += [
            (f"serve_engine_mixed_{name}_tok_per_s", stats.tokens_per_sec, note),
            (f"serve_engine_mixed_{name}_ttft_p50_s", stats.ttft_p50, "arrival->first token"),
            (f"serve_engine_mixed_{name}_ttft_p99_s", stats.ttft_p99, "arrival->first token, tail"),
            (f"serve_engine_mixed_{name}_tpot_p50_s", stats.tpot_p50, "mean inter-token time"),
            (f"serve_engine_mixed_{name}_tpot_p99_s", stats.tpot_p99, "mean inter-token time, tail"),
        ]
    rows.append(
        (
            "serve_engine_mixed_speedup",
            stats_by["unified"].tokens_per_sec
            / max(stats_by["legacy"].tokens_per_sec, 1e-9),
            "unified ragged dispatch vs legacy engine, same arrival stream",
        )
    )
    rows.append(
        (
            # deliberately NOT named *_speedup: a ratio of two p99 tails
            # compounds their noise well past the ±20% gate, so this row is
            # reported/persisted but never gated — the component
            # *_ttft_p99_s rows gate individually against their baselines
            "serve_engine_mixed_ttft_p99_gain",
            stats_by["legacy"].ttft_p99 / max(stats_by["unified"].ttft_p99, 1e-9),
            "TTFT p99 reduction factor (legacy/unified); report-only",
        )
    )
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.6g},{d}")
    return rows


# cluster scenario: a mixed workload in the paper's sense — latency-
# sensitive short requests (scalar-ish, two tenants) interleaved with
# large uniform long prompts (vector-ish) — served by the SAME devices in
# split mode (replicas + JSQ router) and merge mode (one TP engine), with
# the runtime reconfiguration cost measured like the paper's CSR write.
# All rows are report-only trajectory telemetry (check_regression treats
# "_cluster_" like "_mixed_"): open-loop multi-replica runs on a shared
# host are far too alignment-sensitive for the ±20% gate.
CLUSTER_REQUESTS = 24
CLUSTER_MAX_NEW = 8
CLUSTER_SHORT_RANGE = (6, 18)  # latency-sensitive tenants
CLUSTER_LONG_RANGE = (48, 89)  # large uniform kernels
CLUSTER_MEAN_IAT_S = 0.004


def _cluster_stream(cfg, seed: int = 7):
    """Mixed scalar-vector arrival schedule: 2/3 short two-tenant traffic,
    1/3 long uniform prompts; fresh Requests per call."""
    arr = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(CLUSTER_REQUESTS):
        t += float(arr.exponential(CLUSTER_MEAN_IAT_S))
        if i % 3 < 2:
            s = int(arr.integers(*CLUSTER_SHORT_RANGE))
            tenant = f"tenant{i % 2}"
        else:
            s = int(arr.integers(*CLUSTER_LONG_RANGE))
            tenant = None
        out.append(
            (
                t,
                Request(
                    rid=i,
                    prompt=arr.integers(0, cfg.vocab_size, size=s).astype(np.int32),
                    params=SamplingParams(max_new=CLUSTER_MAX_NEW),
                    tenant=tenant,
                ),
            )
        )
    return out


def run_cluster(csv: bool = True) -> list[tuple[str, float, str]]:
    """Split-vs-merge mixed workload on every visible device + the measured
    reconfiguration cost (run under XLA_FLAGS=
    --xla_force_host_platform_device_count=2 on a CPU box)."""
    n_dev = jax.device_count()
    if n_dev < 2:
        print("cluster scenario skipped: needs >= 2 devices "
              f"(have {n_dev}; set XLA_FLAGS=--xla_force_host_platform_device_count=2)")
        return []
    cfg, model, params = _model()
    rows: list[tuple[str, float, str]] = []
    stats_by = {}
    cl = ServeCluster(model, params, batch_slots=4, max_len=96, mode=Mode.SPLIT)
    reconfig_rows: list[tuple[str, float, str]] = []
    for mode in (Mode.SPLIT, Mode.MERGE):
        if cl.mode is not mode:
            rep = cl.reconfigure(mode)  # cold: params/cache placed on the TP fabric
            reconfig_rows.append(
                (
                    "serve_cluster_reconfigure_cold_s",
                    rep.seconds,
                    f"{rep.from_mode}->{rep.to_mode} first switch: "
                    f"{rep.bytes_moved/1e6:.2f} MB placed (compiles excluded; "
                    "prewarm covers them off the serving path)",
                )
            )
        # compiles + warmup drain off the timed region, as in run_mixed
        cl.prewarm()
        rng = np.random.default_rng(1)
        for i, s in enumerate(np.linspace(*CLUSTER_LONG_RANGE, 8).astype(int)):
            cl.submit(
                Request(
                    rid=-1 - i,
                    prompt=rng.integers(0, cfg.vocab_size, size=int(s)).astype(np.int32),
                    params=SamplingParams(max_new=CLUSTER_MAX_NEW),
                )
            )
        cl.run()
        stats = None
        for _ in range(2):  # best-of-2 by throughput, same reasoning as run_mixed
            s = cl.run(arrivals=_cluster_stream(cfg))
            if stats is None or s.tokens_per_sec > stats.tokens_per_sec:
                stats = s
        stats_by[mode] = stats
        name = str(mode)
        note = (
            f"{stats.total_requests} reqs over {n_dev} devices "
            f"({'JSQ router, ' + str(cl.n_replicas) + ' replicas' if mode is Mode.SPLIT else 'one TP engine'})"
        )
        rows += [
            (f"serve_cluster_{name}_tok_per_s", stats.tokens_per_sec, note),
            (f"serve_cluster_{name}_ttft_p99_s", stats.ttft_p99, "arrival->first token, tail"),
            (f"serve_cluster_{name}_tpot_p50_s", stats.tpot_p50, "mean inter-token time"),
        ]
    # warm switch back: the already-built split fabric only resets state —
    # the paper's "reconfiguration is a cheap CSR write once configured"
    rep = cl.reconfigure(Mode.SPLIT)
    reconfig_rows.append(
        (
            "serve_cluster_reconfigure_warm_s",
            rep.seconds,
            f"{rep.from_mode}->{rep.to_mode} warm switch (fabric cached, state reset)",
        )
    )
    rows += reconfig_rows
    rows.append(
        (
            "serve_cluster_split_vs_merge_ratio",
            stats_by[Mode.SPLIT].tokens_per_sec
            / max(stats_by[Mode.MERGE].tokens_per_sec, 1e-9),
            "mixed-workload tok/s, split replicas over merged TP engine "
            "(>1 favors split on this host/stream; report-only)",
        )
    )
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.6g},{d}")
    return rows


# speculative-decoding scenario (all rows report-only, "_spec_" in
# check_regression): low-temperature seeded sampled decode over SELF-PRIMED
# continuation prompts — each prompt is a short random seed plus the model's
# own greedy continuation, so the measured stream continues text the model
# finds predictable (the code-completion regime speculation targets; a
# uniformly random stream would be the drafter's 0%-acceptance worst case
# and is covered by the adaptive-depth floor in the off/ngram delta).  The
# honest comparator is the `_spec_off_` row: the SAME engine, workload and
# seeds with speculation disabled, so the speedup row isolates
# draft-and-verify itself from scenario choices.
SPEC_SEED_LEN = 8
SPEC_PRIME_NEW = 32  # prompt = seed + this many self-generated tokens
SPEC_REQUESTS = 24  # 3 waves over the slots; more requests only dilute
# the rep-end drain tail, measured inside run-to-run variance
SPEC_MAX_NEW = 48
SPEC_TEMP = 0.02  # near-greedy sampled: the gumbel smode, no masked sort
SPEC_SLOTS = 8
SPEC_MAX_LEN = 90  # sized to the workload: 8 seed + 32 prime + 48 new + 1
# depth 1 for the headline n-gram row: on this compute-bound CPU fabric a
# verify row costs linearly (the packed oracle scores every row) while the
# accepted prefix grows sublinearly with depth, so k=1 maximizes tok/s —
# measured 3834 (k=1) vs 3230 (k=4) vs 2796 (k=8, adaptive) at 4 slots.
# Deeper depths are for memory-bound fabrics where extra verify rows ride
# the same weight read; the draft-model row keeps adaptive depth on to
# exercise the EWMA controller end-to-end in CI.
SPEC_K = 1


def _spec_prompts(cfg, model, params):
    """Self-primed continuation prompts, generated once per bench run."""
    eng = ServeEngine(model, params, batch_slots=SPEC_SLOTS,
                      max_len=SPEC_MAX_LEN)
    rng = np.random.default_rng(5)
    seeds = [
        rng.integers(0, cfg.vocab_size, size=SPEC_SEED_LEN).astype(np.int32)
        for _ in range(SPEC_REQUESTS)
    ]
    for i, s in enumerate(seeds):
        eng.submit(Request(
            rid=i, prompt=s, params=SamplingParams(max_new=SPEC_PRIME_NEW),
        ))
    eng.run()
    gen = {r.rid: r.generated for r in eng.finished}
    return [
        np.concatenate([seeds[i], np.asarray(gen[i], np.int32)])
        for i in range(SPEC_REQUESTS)
    ]


def run_spec(csv: bool = True) -> list[tuple[str, float, str]]:
    """Draft-and-verify vs the identical spec-off stream (plus the draft-
    model drafter as a report-only second row)."""
    cfg, model, params = _model()
    prompts = _spec_prompts(cfg, model, params)
    stats_by = {}
    variants = (
        ("off", None),
        ("ngram", SpeculateConfig(mode="ngram", k=SPEC_K)),
        ("draft", SpeculateConfig(mode="draft", k=2, adaptive=True)),
    )
    for name, spec in variants:
        eng = ServeEngine(
            model, params, batch_slots=SPEC_SLOTS, max_len=SPEC_MAX_LEN,
            speculate=spec,
        )
        eng.prewarm(sampling=True)

        def submit(rid0: int) -> None:
            for i, pr in enumerate(prompts):
                eng.submit(Request(
                    rid=rid0 + i, prompt=pr,
                    params=SamplingParams(
                        max_new=SPEC_MAX_NEW, temperature=SPEC_TEMP,
                        seed=abs(rid0) + i,
                    ),
                ))

        # warmup drain: absorbs the drafter's admission-size catch-up
        # compiles (prewarm covers the steady-state shapes)
        submit(-SPEC_REQUESTS)
        eng.run()
        best = None
        for rep in range(3):
            submit(rep * SPEC_REQUESTS)
            stats = eng.run()
            if best is None or stats.tokens_per_sec > best.tokens_per_sec:
                best = stats
        stats_by[name] = best
    off, ng, dr = stats_by["off"], stats_by["ngram"], stats_by["draft"]
    workload = (
        f"{SPEC_REQUESTS} self-primed {SPEC_SEED_LEN + SPEC_PRIME_NEW}-token "
        f"prompts, temp={SPEC_TEMP} seeded, max_new={SPEC_MAX_NEW}, "
        f"{SPEC_SLOTS} slots (best of 3"
    )
    rows = [
        (
            "serve_engine_spec_ngram_tok_per_s",
            ng.tokens_per_sec,
            f"{workload}); n-gram prompt-lookup drafter, depth k={SPEC_K}: "
            f"{ng.spec_acceptance:.0%} drafts accepted, "
            f"{ng.total_tokens / max(ng.spec_ticks, 1):.2f} tokens committed "
            "per verify dispatch",
        ),
        (
            "serve_engine_spec_ngram_acceptance",
            ng.spec_acceptance,
            f"accepted/proposed drafts ({ng.spec_accepted}/{ng.spec_proposed})",
        ),
        (
            "serve_engine_spec_off_tok_per_s",
            off.tokens_per_sec,
            f"{workload}); the SAME stream with speculation off — the "
            "honest comparator for the speedup row",
        ),
        (
            "serve_engine_spec_speedup",
            ng.tokens_per_sec / max(off.tokens_per_sec, 1e-9),
            "n-gram draft-and-verify over spec-off, identical seeded "
            "workload (bit-identical outputs by construction)",
        ),
        (
            "serve_engine_spec_draft_tok_per_s",
            dr.tokens_per_sec,
            f"{workload}); 1-layer truncated-self draft model, adaptive "
            f"depth within k<=2: {dr.spec_acceptance:.0%} accepted — pays a "
            "draft forward pass per tick, wins only when drafts beat the "
            "free n-gram lookup",
        ),
    ]
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.6g},{d}")
    return rows


# paged-KV scenario (all rows report-only, "_paged_" in check_regression):
# the dense engine reserves a worst-case [S_max] cache row per slot, so its
# resident-request ceiling IS batch_slots. The paged pool holds the same
# bytes (byte parity: num_blocks = slots * max_len / block_size) but charges
# each request its ACTUAL rounded-up length, so short-request mixes fit
# several times more concurrent residents — measured below by stepping the
# engine and sampling slot occupancy. The shared-prefix scenario then
# measures the radix tree's admission-TTFT collapse on a repeated
# 512-token system prompt (the tenant-system-prompt serving case).
PAGED_BLOCK_SIZE = 8
PAGED_DENSE_SLOTS = 4  # the dense reference configuration (gated row)
PAGED_MAX_LEN = 96
# three mixes: (name, prompt_len range, max_new) — short requests show the
# capacity headroom, long ones approach dense worst-case (honest floor)
PAGED_MIXES = (
    ("short", (8, 13), 8),
    ("medium", (16, 25), 8),
    ("ragged", (5, 44), 12),
)
PREFIX_SYS_LEN = 512  # repeated system prompt (full blocks of 32)
PREFIX_TAIL_LEN = 16  # per-request unique suffix
PREFIX_BLOCK_SIZE = 32
PREFIX_MAX_LEN = 640
PREFIX_MAX_NEW = 8
PREFIX_REQUESTS = 4


def run_paged(csv: bool = True) -> list[tuple[str, float, str]]:
    """Capacity (resident requests at byte parity) + shared-prefix TTFT."""
    cfg, model, params = _model()
    rows: list[tuple[str, float, str]] = []

    # ---- capacity: same pool bytes as the dense engine, more residents
    pool_blocks = PAGED_DENSE_SLOTS * PAGED_MAX_LEN // PAGED_BLOCK_SIZE
    for mix, (lo, hi), max_new in PAGED_MIXES:
        # slot ceiling high enough that the POOL is the binding resource
        slots = pool_blocks  # one-block requests could in principle fill it
        eng = ServeEngine(
            model, params, batch_slots=slots, max_len=PAGED_MAX_LEN,
            kv_block_size=PAGED_BLOCK_SIZE, num_blocks=pool_blocks,
        )
        rng = np.random.default_rng(0)
        n = 3 * slots  # oversubscribe: admission stops at pool pressure
        for i in range(n):
            s = int(rng.integers(lo, hi))
            eng.submit(
                Request(
                    rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=s).astype(np.int32),
                    params=SamplingParams(max_new=max_new),
                )
            )
        peak = 0
        while eng.step():
            peak = max(peak, sum(r is not None for r in eng.slot_req))
        eng.run()  # drain bookkeeping
        assert eng.pool.free == eng.num_blocks  # nothing leaked
        rows.append(
            (
                f"serve_paged_capacity_{mix}_residents",
                float(peak),
                f"peak concurrent requests, prompts {lo}..{hi - 1} "
                f"max_new {max_new}, pool = dense {PAGED_DENSE_SLOTS} slots x "
                f"{PAGED_MAX_LEN} ({pool_blocks} blocks of {PAGED_BLOCK_SIZE}): "
                f"{peak / PAGED_DENSE_SLOTS:.1f}x the dense ceiling",
            )
        )
    # the steady-state drain at byte parity: tracks what the block-table
    # indirection costs next to the GATED dense serve_engine row
    eng = ServeEngine(
        model, params, batch_slots=PAGED_DENSE_SLOTS, max_len=PAGED_MAX_LEN,
        kv_block_size=PAGED_BLOCK_SIZE,
    )
    rng = np.random.default_rng(0)

    def submit(n: int, rid0: int) -> None:
        for i in range(n):
            s = PROMPT_LENS[i % len(PROMPT_LENS)]
            eng.submit(
                Request(
                    rid=rid0 + i,
                    prompt=rng.integers(0, cfg.vocab_size, size=s).astype(np.int32),
                    params=SamplingParams(max_new=MAX_NEW),
                )
            )

    submit(WARMUP_REQUESTS, rid0=-WARMUP_REQUESTS)
    eng.run()
    best = None
    for rep in range(3):
        submit(MEASURED_REQUESTS, rid0=rep * MEASURED_REQUESTS)
        stats = eng.run()
        if best is None or stats.tokens_per_sec > best.tokens_per_sec:
            best = stats
    rows.append(
        (
            "serve_paged_steady_tok_per_s",
            best.tokens_per_sec,
            f"{best.total_requests} reqs, block-paged pool at byte parity "
            "(compare the gated dense serve_engine_cpu_tok_per_s row)",
        )
    )

    # ---- shared prefix: repeated system prompt, radix tree on vs off
    rng = np.random.default_rng(2)
    sys_p = rng.integers(0, cfg.vocab_size, size=PREFIX_SYS_LEN).astype(np.int32)

    def prefix_reqs(rid0: int):
        r = np.random.default_rng(rid0 + 100)
        return [
            Request(
                rid=rid0 + i,
                prompt=np.concatenate(
                    [sys_p, r.integers(0, cfg.vocab_size,
                                       size=PREFIX_TAIL_LEN).astype(np.int32)]
                ),
                params=SamplingParams(max_new=PREFIX_MAX_NEW),
            )
            for i in range(PREFIX_REQUESTS)
        ]

    ttft = {}
    for on in (False, True):
        eng = ServeEngine(
            model, params, batch_slots=PREFIX_REQUESTS,
            max_len=PREFIX_MAX_LEN, kv_block_size=PREFIX_BLOCK_SIZE,
            prefix_cache=on,
        )
        # warmup request: compiles the pack ladder AND (prefix on) leaves
        # the system prompt resident in the tree — the serving steady state
        # for a tenant whose system prompt has been seen once
        eng.submit(prefix_reqs(-10)[0])
        eng.run()
        best = None
        for rep in range(2):
            for r in prefix_reqs(rep * PREFIX_REQUESTS):
                eng.submit(r)
            stats = eng.run()
            if best is None or stats.ttft_p50 < best.ttft_p50:
                best = stats
        ttft[on] = best
        name = "on" if on else "off"
        note = (
            f"{PREFIX_REQUESTS} reqs sharing a {PREFIX_SYS_LEN}-token system "
            f"prompt + {PREFIX_TAIL_LEN}-token tails, prefix cache {name}"
        )
        if on:
            st = eng.prefix.stats()
            note += (
                f"; tree skipped {st.hit_tokens} prompt tokens "
                f"({st.hits}/{st.lookups} lookups hit)"
            )
        rows.append((f"serve_paged_prefix_{name}_ttft_p50_s", best.ttft_p50, note))
    rows.append(
        (
            "serve_paged_prefix_ttft_gain",
            ttft[False].ttft_p50 / max(ttft[True].ttft_p50, 1e-9),
            "admission TTFT p50 reduction, prefix cache off/on "
            "(the radix tree collapses the shared 512-token prefill)",
        )
    )
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.6g},{d}")
    return rows


# quantized-serving scenario (all rows report-only, "_quant_" in
# check_regression): the int8 KV cache stores (head_dim + 4) bytes per
# (position, head) row — int8 payload + one f32 scale — against f32's
# 4 * head_dim, so a BYTE-parity pool holds ~3-4x the blocks and the
# capacity pattern admits correspondingly more concurrent residents. The
# steady row tracks what in-kernel dequant costs next to the GATED dense
# f32 serve_engine row (which this PR leaves byte-identical: quantization
# is opt-in); the weight row adds int8 matmul weights on top.
QUANT_CAPACITY_MIX = ("short", (8, 13), 8)  # the max-headroom paged mix


def _kv_bytes_per_block(model, block_size: int, kv_dtype) -> int:
    """Measured HBM bytes of ONE pool block (all layers, K+V+scales)."""
    pool = model.init_kv_pool(1, block_size, kv_dtype=kv_dtype)
    return sum(int(leaf.nbytes) for leaf in jax.tree.leaves(pool))


def _quant_capacity(model, params, cfg, num_blocks: int, kv_dtype) -> int:
    """Peak concurrent residents on a ``num_blocks`` pool (the run_paged
    capacity pattern: oversubscribe, step, sample slot occupancy)."""
    _, (lo, hi), max_new = QUANT_CAPACITY_MIX
    slots = num_blocks  # slot ceiling high enough that the pool binds
    eng = ServeEngine(
        model, params, batch_slots=slots,
        max_len=PAGED_MAX_LEN, kv_block_size=PAGED_BLOCK_SIZE,
        num_blocks=num_blocks, kv_dtype=kv_dtype,
    )
    rng = np.random.default_rng(0)
    for i in range(3 * slots):
        s = int(rng.integers(lo, hi))
        eng.submit(
            Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=s).astype(np.int32),
                params=SamplingParams(max_new=max_new),
            )
        )
    peak = 0
    while eng.step():
        peak = max(peak, sum(r is not None for r in eng.slot_req))
    eng.run()  # drain bookkeeping
    assert eng.pool.free == eng.num_blocks  # nothing leaked
    return peak


def run_quant(csv: bool = True) -> list[tuple[str, float, str]]:
    """Quantized serving: int8-KV steady drain + capacity at byte parity."""
    cfg, model, params = _model()
    rows: list[tuple[str, float, str]] = []

    # ---- steady-state drains: the dense-engine pattern from run(), once
    # with the int8 KV cache and once with int8 weights stacked on top
    for name, eng_kw, note in (
        (
            "serve_quant_steady_tok_per_s",
            dict(kv_dtype="int8"),
            "int8 KV rows + per-(pos, head) f32 scales, dequant in-kernel "
            "(compare the gated f32 serve_engine_cpu_tok_per_s row)",
        ),
        (
            "serve_quant_w8_steady_tok_per_s",
            dict(kv_dtype="int8", weight_dtype="int8"),
            "int8 KV AND int8 per-output-channel matmul weights "
            "(qweight read-through dequant per scanned layer)",
        ),
    ):
        eng = ServeEngine(model, params, batch_slots=4, max_len=96, **eng_kw)
        rng = np.random.default_rng(0)

        def submit(n: int, rid0: int) -> None:
            for i in range(n):
                s = PROMPT_LENS[i % len(PROMPT_LENS)]
                eng.submit(
                    Request(
                        rid=rid0 + i,
                        prompt=rng.integers(
                            0, cfg.vocab_size, size=s
                        ).astype(np.int32),
                        params=SamplingParams(max_new=MAX_NEW),
                    )
                )

        submit(WARMUP_REQUESTS, rid0=-WARMUP_REQUESTS)
        eng.run()
        best = None
        for rep in range(3):
            submit(MEASURED_REQUESTS, rid0=rep * MEASURED_REQUESTS)
            stats = eng.run()
            if best is None or stats.tokens_per_sec > best.tokens_per_sec:
                best = stats
        rows.append(
            (
                name,
                best.tokens_per_sec,
                f"{best.total_requests} reqs, {best.ticks} ticks, "
                f"peak resident KV {best.kv_bytes_resident:,} B; " + note,
            )
        )

    # ---- capacity at BYTE parity: both pools hold the bytes of the dense
    # f32 cache (slots * max_len positions); the int8 pool turns the same
    # byte budget into ~3-4x the blocks and admits more residents
    bpb_f32 = _kv_bytes_per_block(model, PAGED_BLOCK_SIZE, None)
    bpb_q8 = _kv_bytes_per_block(model, PAGED_BLOCK_SIZE, "int8")
    blocks_f32 = PAGED_DENSE_SLOTS * PAGED_MAX_LEN // PAGED_BLOCK_SIZE
    byte_budget = blocks_f32 * bpb_f32
    blocks_q8 = byte_budget // bpb_q8
    peak_f32 = _quant_capacity(model, params, cfg, blocks_f32, None)
    peak_q8 = _quant_capacity(model, params, cfg, int(blocks_q8), "int8")
    mix, (lo, hi), max_new = QUANT_CAPACITY_MIX
    rows.append(
        (
            "serve_quant_bytes_per_block_ratio",
            bpb_f32 / bpb_q8,
            f"f32 {bpb_f32} B/block vs int8+scales {bpb_q8} B/block "
            f"({PAGED_BLOCK_SIZE} positions, all layers)",
        )
    )
    rows.append(
        (
            f"serve_quant_capacity_{mix}_residents",
            float(peak_q8),
            f"peak concurrent requests, prompts {lo}..{hi - 1} max_new "
            f"{max_new}, int8 pool of {blocks_q8} blocks at byte parity "
            f"with the f32 pool's {blocks_f32} ({byte_budget:,} B)",
        )
    )
    rows.append(
        (
            "serve_quant_capacity_gain_x",
            peak_q8 / max(peak_f32, 1),
            f"int8 residents / f32 residents at the same pool bytes "
            f"({peak_q8} vs {peak_f32})",
        )
    )
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.6g},{d}")
    return rows


# overload scenario (all rows report-only, "_overload_" in check_regression):
# an arrival burst far beyond capacity hits the SAME single-replica cluster
# three ways — uncongested (wide spacing: the latency floor), ungated
# (no admission: TTFT grows with queue position, the unbounded baseline),
# and gated (admission control + per-request TTFT deadlines under
# run_controlled: excess load is shed up front, the admitted remainder
# keeps near-uncongested tails). The claim under test is the robustness
# invariant: admitted p99 TTFT stays within 2x the uncongested p99 while
# the ungated baseline's p99 grows with burst size.
OVERLOAD_REQUESTS = 48
OVERLOAD_PROMPT_LEN = 8
OVERLOAD_MAX_NEW = 8
OVERLOAD_IAT_S = 0.0005  # burst: far below per-request service time
UNCONGESTED_IAT_S = 0.08  # wide spacing: each request sees an idle engine
OVERLOAD_DEADLINE_MULT = 2.0  # deadline = mult * measured uncongested p99
OVERLOAD_MAX_QUEUE = 6
OVERLOAD_INTERVAL_S = 0.05  # control interval for run_controlled


def _overload_reqs(cfg, n: int, iat: float, seed: int = 9,
                   deadline_s: float | None = None):
    rng = np.random.default_rng(seed)
    return [
        (
            i * iat,
            Request(
                rid=i,
                prompt=rng.integers(
                    0, cfg.vocab_size, size=OVERLOAD_PROMPT_LEN
                ).astype(np.int32),
                params=SamplingParams(max_new=OVERLOAD_MAX_NEW, seed=100 + i),
                tenant=f"tenant{i % 2}",
                deadline_s=deadline_s,
            ),
        )
        for i in range(n)
    ]


def _ttft_p99(reqs) -> float:
    served = sorted(
        r.first_token_at - r.submitted_at
        for r in reqs
        if r.finish_reason in ("length", "stop") and r.first_token_at > 0
    )
    if not served:
        return float("nan")
    return served[min(len(served) - 1, int(0.99 * len(served)))]


def run_overload(csv: bool = True) -> list[tuple[str, float, str]]:
    """Overload survival: shed rate + admitted-tail TTFT vs the ungated
    baseline, single-replica cluster on the default device."""
    from repro.serve import AdmissionPolicy
    from repro.serve.controller import ReconfigController

    cfg, model, params = _model()
    dev = [jax.devices()[0]]

    # uncongested floor: wide spacing, no admission needed
    cl = ServeCluster(model, params, batch_slots=4, max_len=96, devices=dev)
    cl.prewarm(sampling=True)
    unc = _overload_reqs(cfg, 12, UNCONGESTED_IAT_S)
    stats = cl.run(unc)
    unc_p99 = _ttft_p99([r for _, r in unc])
    served_rate = sum(r.n_generated for _, r in unc) / stats.wall_seconds

    # ungated baseline: the whole burst queues, TTFT grows with position
    cl = ServeCluster(model, params, batch_slots=4, max_len=96, devices=dev)
    cl.prewarm(sampling=True)
    base = _overload_reqs(cfg, OVERLOAD_REQUESTS, OVERLOAD_IAT_S)
    cl.run(base)
    base_p99 = _ttft_p99([r for _, r in base])

    # gated: admission control + deadlines under the closed control loop
    deadline = OVERLOAD_DEADLINE_MULT * unc_p99
    cl = ServeCluster(
        model, params, batch_slots=4, max_len=96, devices=dev,
        admission=AdmissionPolicy(
            max_queue=OVERLOAD_MAX_QUEUE, initial_tok_per_s=served_rate,
        ),
    )
    cl.prewarm(sampling=True)
    gated = _overload_reqs(
        cfg, OVERLOAD_REQUESTS, OVERLOAD_IAT_S, deadline_s=deadline
    )
    ctl = ReconfigController.for_cluster(cl, interval_s=OVERLOAD_INTERVAL_S)
    gstats = cl.run_controlled(gated, controller=ctl)
    greqs = [r for _, r in gated]
    adm_p99 = _ttft_p99(greqs)
    n_shed = sum(r.finish_reason == "rejected" for r in greqs)
    n_admitted = len(greqs) - n_shed

    burst = (
        f"{OVERLOAD_REQUESTS} reqs at {OVERLOAD_IAT_S * 1e3:.1f}ms IAT, "
        f"1 replica, 4 slots"
    )
    rows = [
        (
            "serve_overload_uncongested_ttft_p99_s",
            unc_p99,
            f"12 reqs at {UNCONGESTED_IAT_S * 1e3:.0f}ms IAT: the latency "
            "floor the admitted tail is held against",
        ),
        (
            "serve_overload_baseline_ttft_p99_s",
            base_p99,
            f"{burst}, NO admission: {base_p99 / max(unc_p99, 1e-9):.1f}x "
            "the uncongested p99 — grows with burst size",
        ),
        (
            "serve_overload_admitted_ttft_p99_s",
            adm_p99,
            f"{burst}, admission on (max_queue={OVERLOAD_MAX_QUEUE}, "
            f"deadline={OVERLOAD_DEADLINE_MULT:.0f}x uncongested p99): "
            f"{n_admitted} admitted at "
            f"{adm_p99 / max(unc_p99, 1e-9):.2f}x the uncongested p99",
        ),
        (
            "serve_overload_admitted_ttft_ratio",
            adm_p99 / max(unc_p99, 1e-9),
            "admitted p99 / uncongested p99 — the robustness invariant is "
            "<= 2.0 while the baseline ratio grows unboundedly",
        ),
        (
            "serve_overload_shed_rate",
            n_shed / len(greqs),
            f"{n_shed}/{len(greqs)} shed "
            f"(stats: shed={gstats.shed} rejected={gstats.rejected} "
            f"queue_peak={gstats.queue_peak}; baseline queue_peak bound only "
            "by burst size)",
        ),
    ]
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.6g},{d}")
    return rows


# heterogeneous scenario: a mixed tenant stream (latency-sensitive chat
# tenants pinned to the MLA model, bulk tenants to the constant-memory SSM
# model) over a 2-replica split cluster with one model per replica. The
# router dispatches by model name; the rows report per-model latency and
# the SSM capacity story (constant state bytes vs the attention replica's
# length-proportional cache).
HETERO_REQUESTS = 24
HETERO_MAX_NEW = 8
HETERO_PROMPT_RANGE = (8, 41)
HETERO_IAT_S = 0.004


def _hetero_models():
    cfg_a = get_arch("minicpm3-4b").reduced()  # dense + MLA latents
    cfg_b = get_arch("falcon-mamba-7b").reduced()  # pure mamba1
    m_a, m_b = LM(cfg_a), LM(cfg_b)
    return (
        (cfg_a, m_a, m_a.init(jax.random.key(0))),
        (cfg_b, m_b, m_b.init(jax.random.key(1))),
    )


def _hetero_stream(cfg_a, cfg_b, seed: int = 13):
    rng = np.random.default_rng(seed)
    tenants = ("chat0", "chat1", "bulk0", "bulk1")
    out = []
    for i in range(HETERO_REQUESTS):
        tenant = tenants[i % len(tenants)]
        cfg = cfg_a if tenant.startswith("chat") else cfg_b
        plen = int(rng.integers(*HETERO_PROMPT_RANGE))
        out.append(
            (
                i * HETERO_IAT_S,
                Request(
                    rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=plen).astype(
                        np.int32
                    ),
                    params=SamplingParams(max_new=HETERO_MAX_NEW),
                    tenant=tenant,
                ),
            )
        )
    return out


def run_hetero(csv: bool = True) -> list[tuple[str, float, str]]:
    """Heterogeneous split cluster: MLA + SSM models behind the
    model-aware router, mixed per-tenant stream. Report-only (_hetero_)
    trajectory rows; the bit-identity and typed-rejection invariants are
    pinned in tests."""
    (cfg_a, m_a, p_a), (cfg_b, m_b, p_b) = _hetero_models()
    devs = jax.devices()
    # one replica per model: two real devices when the lane has them, two
    # engines time-sharing one device otherwise (same routing semantics)
    pair = list(devs[:2]) if len(devs) >= 2 else [devs[0], devs[0]]
    cl = ServeCluster(
        models={"mla": (m_a, p_a), "ssm": (m_b, p_b)},
        tenant_models={
            "chat0": "mla", "chat1": "mla", "bulk0": "ssm", "bulk1": "ssm",
        },
        batch_slots=4, max_len=96, devices=pair,
    )
    cl.prewarm()
    stream = _hetero_stream(cfg_a, cfg_b)
    stats = cl.run(stream)
    reqs = [r for _, r in stream]
    mla_reqs = [r for r in reqs if r.model == "mla"]
    ssm_reqs = [r for r in reqs if r.model == "ssm"]
    plan = cl.replica_plan()
    eng_mla = cl.engines[plan["mla"][0]]
    eng_ssm = cl.engines[plan["ssm"][0]]
    toks = sum(len(r.generated) for r in reqs)
    rows = [
        (
            "serve_hetero_tok_per_s",
            toks / max(stats.wall_seconds, 1e-9),
            f"{HETERO_REQUESTS} reqs ({len(mla_reqs)} MLA + {len(ssm_reqs)} "
            f"SSM) at {HETERO_IAT_S * 1e3:.0f}ms IAT over one replica per "
            "model, routed by tenant",
        ),
        (
            "serve_hetero_mla_ttft_p99_s",
            _ttft_p99(mla_reqs),
            "chat tenants on the MLA replica (compressed latent cache)",
        ),
        (
            "serve_hetero_ssm_ttft_p99_s",
            _ttft_p99(ssm_reqs),
            "bulk tenants on the SSM replica (constant recurrent state)",
        ),
        (
            "serve_hetero_ssm_kv_bytes",
            float(eng_ssm.kv_bytes_resident()),
            "SSM replica state bytes — constant in max_len AND in tokens "
            "served (no block pool, nothing paged)",
        ),
        (
            "serve_hetero_kv_bytes_ratio",
            eng_mla.kv_bytes_resident() / max(eng_ssm.kv_bytes_resident(), 1),
            "attention-replica KV bytes / SSM-replica state bytes at the "
            "same slots+max_len — the capacity flex of pinning SSM bulk "
            "traffic onto its own replica",
        ),
    ]
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.6g},{d}")
    return rows


def _write_json(path: str, rows, benchmark: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {
        "benchmark": benchmark,
        "devices": jax.device_count(),
        "jax": jax.__version__,
        "rows": [{"name": n, "value": v, "note": d} for n, v, d in rows],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {len(rows)} rows -> {path}")


def main() -> None:
    """CLI entry point (the CI bench-smoke job): CSV to stdout, optional JSON
    artifacts comparable across commits via benchmarks.check_regression."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH", help="write steady-state rows as JSON")
    ap.add_argument(
        "--mixed-json", default=None, metavar="PATH",
        help="write mixed-arrival rows as JSON (also enables the scenario)",
    )
    ap.add_argument(
        "--skip-steady", action="store_true",
        help="run only the mixed-arrival scenario",
    )
    ap.add_argument(
        "--sampled-json", default=None, metavar="PATH",
        help="write sampled-decode (top-p stream) rows as JSON "
        "(also enables the scenario; report-only trajectory rows)",
    )
    ap.add_argument(
        "--cluster", action="store_true",
        help="run ONLY the split-vs-merge cluster scenario (needs >= 2 devices)",
    )
    ap.add_argument(
        "--cluster-json", default=None, metavar="PATH",
        help="write cluster rows as JSON (implies --cluster)",
    )
    ap.add_argument(
        "--paged-json", default=None, metavar="PATH",
        help="write paged-KV capacity + shared-prefix rows as JSON "
        "(also enables the scenario; report-only trajectory rows)",
    )
    ap.add_argument(
        "--spec-json", default=None, metavar="PATH",
        help="write speculative-decoding rows as JSON (also enables the "
        "scenario; report-only trajectory rows)",
    )
    ap.add_argument(
        "--overload-json", default=None, metavar="PATH",
        help="write overload-survival rows (admission control + load "
        "shedding vs the ungated baseline) as JSON (also enables the "
        "scenario; report-only trajectory rows)",
    )
    ap.add_argument(
        "--quant-json", default=None, metavar="PATH",
        help="write quantized-serving rows (int8-KV steady drain + "
        "capacity at byte parity) as JSON (also enables the scenario; "
        "report-only trajectory rows)",
    )
    ap.add_argument(
        "--hetero-json", default=None, metavar="PATH",
        help="write heterogeneous-cluster rows (mixed MLA + SSM tenant "
        "stream, one model per split replica) as JSON (also enables the "
        "scenario; report-only trajectory rows)",
    )
    args = ap.parse_args()

    if args.cluster or args.cluster_json is not None:
        cluster_rows = run_cluster(csv=True)
        if args.cluster_json:
            _write_json(args.cluster_json, cluster_rows, "serving_cluster")
        return

    if not args.skip_steady:
        rows = run(csv=True)
        if args.json:
            _write_json(args.json, rows, "serving")
    if args.sampled_json is not None:
        sampled = run_sampled(csv=True)
        _write_json(args.sampled_json, sampled, "serving_sampled")
    # bare --skip-steady means "mixed only"; with a scenario-specific
    # --*-json it means "that scenario only" (each CI step runs its own)
    if args.mixed_json is not None or (
        args.skip_steady and args.paged_json is None
        and args.spec_json is None and args.overload_json is None
        and args.quant_json is None and args.hetero_json is None
    ):
        mixed = run_mixed(csv=True)
        if args.mixed_json:
            _write_json(args.mixed_json, mixed, "serving_mixed")
    if args.paged_json is not None:
        paged = run_paged(csv=True)
        _write_json(args.paged_json, paged, "serving_paged")
    if args.spec_json is not None:
        spec = run_spec(csv=True)
        _write_json(args.spec_json, spec, "serving_spec")
    if args.overload_json is not None:
        ov = run_overload(csv=True)
        _write_json(args.overload_json, ov, "serving_overload")
    if args.quant_json is not None:
        quant = run_quant(csv=True)
        _write_json(args.quant_json, quant, "serving_quant")
    if args.hetero_json is not None:
        het = run_hetero(csv=True)
        _write_json(args.hetero_json, het, "serving_hetero")


if __name__ == "__main__":
    main()
