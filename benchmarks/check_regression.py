"""Bench-regression gate: compare fresh benchmark JSON against the committed
baseline (``benchmarks/results/baseline.json``) with a wall-clock tolerance.

Direction is inferred from the row name: throughput-like rows
(``*tok_per_s``, ``*speedup*``) must not DROP more than the tolerance;
time/energy-like rows (``*_ms``, ``*_us``, ``*_s``, ``*_rel``, ``*_seconds``)
must not GROW more than the tolerance. Rows present on only one side are
reported but never fail the gate (new benchmarks don't need a baseline
backfill to land). Exit code 1 on any regression — this fails the CI
bench-smoke job.

Usage:
    python -m benchmarks.check_regression current.json [current2.json ...] \
        [--baseline benchmarks/results/baseline.json] [--tolerance 0.2]

Refreshing the baseline after an intentional perf change:
    python -m benchmarks.serving_bench --json /tmp/serving.json
    python -m benchmarks.kernels_modes --tiny --json /tmp/kernels.json
    python -m benchmarks.check_regression /tmp/serving.json /tmp/kernels.json \
        --update-baseline
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys

DEFAULT_BASELINE = os.path.join(
    os.path.dirname(__file__), "results", "baseline.json"
)

_HIGHER_BETTER = ("tok_per_s", "speedup")
_LOWER_BETTER = ("_ms", "_us", "_s", "_seconds", "_rel")
# rows whose absolute value depends on the machine that measured them:
# gated only when the current host fingerprint matches the baseline's.
# All per-request latency rows (serve_engine_*_ttft_*/_tpot_*) ride the
# "serve_engine" prefix — wall-clock through and through.
_MACHINE_DEPENDENT = ("cpu_measured", "serve_engine")
# open-loop arrival scenarios: run-to-run variance on a shared host
# exceeds any sane tolerance (arrival alignment with tick boundaries
# reshuffles the whole schedule — observed 1.0x-1.35x swings of the SAME
# code). Reported and persisted for the per-PR trajectory, never gated;
# the steady-state best-of-N rows are the enforceable serving gate.
# "_cluster_" rows (split-vs-merge multi-replica runs + reconfigure cost)
# are open-loop AND thread-scheduling dependent — same treatment.
# "_sampled_" rows (the top-p sampled-decode scenario) are trajectory
# telemetry for the fused sampler's cost; the enforceable serving gate is
# the ALL-GREEDY steady-state row (serve_engine_cpu_tok_per_s), which the
# sampler redesign must leave inside ±20% of the committed baseline.
# "_spec_" rows (speculative decoding) are acceptance-rate dependent —
# throughput swings with how predictable the self-primed stream happens to
# be on a given parameter init — so they ride as trajectory rows while the
# greedy and sampled steady rows gate spec-off parity.
# "_overload_" rows (admission control + load shedding under an arrival
# burst) are open-loop AND threshold-sensitive: the shed count flips on
# how arrivals align with control-interval boundaries, so the rows ride
# as trajectory telemetry while tests/test_serve_cluster.py asserts the
# actual invariant (shedding engages, admitted tail bounded).
# "_quant_" rows (int8 KV/weight serving) are wall-clock on the steady
# drain and pool-layout dependent on the capacity pattern; the enforceable
# invariants (f32-lane bit-identity, capacity gain at byte parity, TV /
# greedy-agreement quality gates) live in tests/test_quant_serving.py.
# "_hetero_" rows (multi-model split cluster: MLA + SSM replicas behind
# the model-aware router) are open-loop AND thread-scheduling dependent
# like _cluster_; the enforceable invariants (per-model routing
# bit-identity, constant SSM state bytes, same-model-only re-homing) live
# in tests/test_serve.py and tests/test_serve_cluster.py.
_REPORT_ONLY = (
    "_mixed_", "_cluster_", "_sampled_", "_paged_", "_spec_", "_overload_",
    "_quant_", "_hetero_",
)


def host_fingerprint() -> dict:
    """Identity of the measuring host. Deliberately strict (includes the
    hostname): machine-dependent wall-clock rows only gate against a
    baseline recorded on the SAME host — a 2-vCPU CI runner and a 2-vCPU
    laptop are not comparable at ±20%. Modeled/analytic rows always gate
    regardless, so CI still catches perf-model regressions."""
    return {
        "cpu_count": os.cpu_count(),
        "machine": platform.machine(),
        "node": platform.node(),
    }


def row_direction(name: str) -> str:
    """'up' (higher is better), 'down' (lower is better), or 'skip'."""
    if any(t in name for t in _HIGHER_BETTER):
        return "up"
    if name.endswith(_LOWER_BETTER):
        return "down"
    return "skip"


def load_rows(path: str) -> dict[str, float]:
    with open(path) as f:
        payload = json.load(f)
    return {r["name"]: float(r["value"]) for r in payload.get("rows", [])}


def check(
    current: dict[str, float],
    baseline: dict[str, float],
    tolerance: float,
    same_host: bool = True,
) -> list[str]:
    """Returns a list of human-readable regression descriptions (empty = ok).

    With ``same_host=False`` (the baseline was recorded on different
    hardware), machine-dependent wall-clock rows are reported but never
    fail the gate — a 2-vCPU CI runner measuring 1.8x the laptop baseline
    is hardware, not a regression. Modeled/analytic rows always gate.
    """
    regressions = []
    for name, base in sorted(baseline.items()):
        if name not in current:
            print(f"  [absent ] {name} (baseline {base:.6g}) — not checked")
            continue
        cur = current[name]
        direction = row_direction(name)
        if any(t in name for t in _REPORT_ONLY):
            print(f"  [info   ] {name}: {cur:.6g} vs {base:.6g} (trajectory row)")
            continue
        if not same_host and any(t in name for t in _MACHINE_DEPENDENT):
            print(f"  [no-gate] {name}: {cur:.6g} vs {base:.6g} (different host)")
            continue
        if direction == "skip" or base == 0:
            print(f"  [skipped] {name}: {cur:.6g}")
            continue
        ratio = cur / base
        if direction == "up":
            bad = ratio < 1.0 - tolerance
            arrow = "↑ok" if ratio >= 1.0 else "↓"
        else:
            bad = ratio > 1.0 + tolerance
            arrow = "↓ok" if ratio <= 1.0 else "↑"
        status = "REGRESSED" if bad else "ok"
        print(
            f"  [{status:9s}] {name}: {cur:.6g} vs baseline {base:.6g} "
            f"({ratio:.3f}x {arrow}, tol ±{tolerance:.0%})"
        )
        if bad:
            regressions.append(f"{name}: {cur:.6g} vs {base:.6g} ({ratio:.3f}x)")
    for name in sorted(set(current) - set(baseline)):
        print(f"  [new    ] {name}: {current[name]:.6g} — no baseline yet")
    return regressions


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", nargs="+", help="fresh benchmark JSON file(s)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--tolerance", type=float, default=float(
        os.environ.get("REPRO_BENCH_TOLERANCE", "0.2")
    ))
    ap.add_argument(
        "--update-baseline", action="store_true",
        help="rewrite the baseline from the current rows instead of checking",
    )
    args = ap.parse_args()

    current: dict[str, float] = {}
    for path in args.current:
        current.update(load_rows(path))

    if args.update_baseline:
        payload = {
            "note": "committed bench baseline; refresh via check_regression --update-baseline",
            "tolerance": args.tolerance,
            "host": host_fingerprint(),
            "rows": [
                {"name": n, "value": v} for n, v in sorted(current.items())
            ],
        }
        os.makedirs(os.path.dirname(args.baseline) or ".", exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"baseline updated: {args.baseline} ({len(current)} rows)")
        return 0

    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline}; nothing to check")
        return 0
    baseline = load_rows(args.baseline)
    base_host = json.load(open(args.baseline)).get("host")
    same_host = base_host == host_fingerprint()
    print(
        f"checking {len(current)} rows against {args.baseline} "
        f"(host match: {same_host}):"
    )
    regressions = check(current, baseline, args.tolerance, same_host=same_host)
    if regressions:
        print(f"\n{len(regressions)} bench regression(s) beyond ±{args.tolerance:.0%}:")
        for r in regressions:
            print(f"  {r}")
        return 1
    print("\nbench gate: no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
