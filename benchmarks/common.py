"""Shared benchmark plumbing: the paper's six kernels as workload specs.

Each kernel gets (a) a runnable jnp/ops implementation for measured-on-CPU
mechanism checks, and (b) an analytic KernelCost at PRODUCTION size for the
v5e performance model (this container has one CPU core — wall-clock cannot
express fabric scaling; see repro.core.perfmodel docstring).
"""

from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.perfmodel import KernelCost

# production-size analytic costs (global FLOPs / HBM bytes per invocation);
# sized so one invocation runs ~5-30 ms on a 256-chip pod — large enough that
# the 30 µs dispatch / 100 µs barrier constants are the paper-like few-%
# effect, not the dominant term.
PAPER_KERNELS: dict[str, KernelCost] = {
    # C = A@B: (8·32k) × 32k × 32k bf16
    "fmatmul": KernelCost(
        "fmatmul", flops=2 * 8 * 32768**3, hbm_bytes=(2 * 8 + 1) * 32768**2 * 2
    ),
    # conv2d: 2048×512×512×256 -> 256 out ch, 3x3
    "fconv2d": KernelCost(
        "fconv2d",
        flops=2 * 2048 * 510 * 510 * 256 * 256 * 9,
        hbm_bytes=2 * (2048 * 512 * 512 * 256 + 2048 * 510 * 510 * 256),
    ),
    # batched FFT: 2^19 rows of 16k points (5 N log2 N real flops per row)
    "fft": KernelCost(
        "fft",
        flops=2**19 * 5 * 16384 * 14,
        hbm_bytes=2 * 2**19 * 16384 * 8,
    ),
    # dotp over 2^37 elements
    "dotp": KernelCost("dotp", flops=2 * 2**37, hbm_bytes=2 * 2**37 * 4),
    # axpy over 2^36 elements
    "axpy": KernelCost("axpy", flops=2 * 2**36, hbm_bytes=3 * 2**36 * 4),
    # softmax over 2^25 rows × 4k cols
    "softmax": KernelCost(
        "softmax", flops=5 * 2**25 * 4096, hbm_bytes=2 * 2**25 * 4096 * 2
    ),
}


def measured_kernels(scale: int = 256) -> dict[str, Callable[[], None]]:
    """Tiny runnable versions (CPU mechanism checks). Each returns a thunk
    that executes one jitted invocation and blocks."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((scale, scale)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((scale, scale)), jnp.float32)
    img = jnp.asarray(rng.standard_normal((2, 32, 32, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 16, 16)), jnp.float32)
    vec = jnp.asarray(rng.standard_normal(scale * scale), jnp.float32)
    re = jnp.asarray(rng.standard_normal((64, 512)), jnp.float32)
    im = jnp.zeros((64, 512), jnp.float32)
    sm = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)

    from repro.kernels import ref

    fns = {
        "fmatmul": jax.jit(lambda: ref.matmul(a, b)),
        "fconv2d": jax.jit(lambda: ref.conv2d(img, w)),
        "fft": jax.jit(lambda: ref.fft(re, im)),
        "dotp": jax.jit(lambda: ref.dotp(vec, vec)),
        "axpy": jax.jit(lambda: ref.axpy(2.0, vec, vec)),
        "softmax": jax.jit(lambda: ref.softmax(sm)),
    }
    return {k: (lambda f=f: jax.block_until_ready(f())) for k, f in fns.items()}


def time_thunk(thunk: Callable[[], None], repeats: int = 5) -> float:
    thunk()  # warmup
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        thunk()
        best = min(best, time.perf_counter() - t0)
    return best
