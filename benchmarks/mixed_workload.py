"""Paper Fig. 2 (right): mixed scalar-vector workload, MM speedup over SM.

The scalar side (CoreMark analogue) is MEASURED on this host — it is real
Python control work. The vector side is modeled on the v5e fabric (1-core
container; see perfmodel docstring). The schedule logic mirrors
repro.core.scheduler exactly:

  SM: controller-1 consumed by the scalar queue (its pod idles);
      all vector kernels run on pod-0's 256 chips.
  MM: one controller drives all 512 chips; scalar work fully overlaps on
      the freed controller.

Also runs the REAL MixedScheduler end-to-end on this host with tiny kernels
(mechanism check: threads, queues, overlap bookkeeping)."""

from __future__ import annotations

import time

from repro.core import (
    Mode,
    MixedScheduler,
    ScalarTask,
    SpatzformerCluster,
    VectorTask,
    coremark,
)
from repro.core.perfmodel import model_mixed_merge, model_mixed_split

from benchmarks.common import PAPER_KERNELS, measured_kernels

CHIPS_PER_POD = 256
PODS = 2


def run(csv: bool = True) -> list[tuple[str, float, str]]:
    rows = []
    # measured scalar task, three load points: the MM gain depends on the
    # scalar:vector ratio (paper's setup is the vector-dominated regime)
    for label, iters in (("light", 20), ("medium", 100), ("heavy", 400)):
        cm = coremark(iters)
        rows.append(
            (f"coremark_{label}_measured_s", cm.seconds, f"checksum={cm.checksum:#06x}")
        )
        speedups = []
        for name, cost in PAPER_KERNELS.items():
            stream = [cost] * 8
            sm = model_mixed_split(stream, cm.seconds, CHIPS_PER_POD)
            mm = model_mixed_merge(stream, cm.seconds, CHIPS_PER_POD * PODS)
            s = sm.makespan / mm.makespan
            speedups.append(s)
            if label == "light":
                rows.append(
                    (
                        f"mixed_{name}_MM_speedup",
                        s,
                        f"SM={sm.makespan*1e3:.1f}ms MM={mm.makespan*1e3:.1f}ms",
                    )
                )
        rows.append(
            (
                f"mixed_avg_MM_speedup_{label}",
                sum(speedups) / len(speedups),
                "paper: avg 1.8x, up to ~2x (vector-dominated)",
            )
        )

    # mechanism check: real scheduler, tiny kernels, this host
    cl = SpatzformerCluster(n_pods=1, pod_shape=(1, 1))
    sched = MixedScheduler(cl)
    meas = measured_kernels(scale=128)
    vts = [VectorTask(k, lambda info, f=f: f()) for k, f in meas.items()]
    sts = [ScalarTask("coremark", lambda: coremark(2).checksum)]
    t0 = time.perf_counter()
    rep = sched.run(Mode.MERGE, vts, sts)
    rows.append(
        (
            "scheduler_mechanism_makespan_s",
            rep.makespan,
            f"records={len(rep.records)} lanes={len({r.lane for r in rep.records})}",
        )
    )
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.6g},{d}")
    return rows


if __name__ == "__main__":
    run()
