"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
benchmarks/results/dryrun.jsonl.

    PYTHONPATH=src python -m benchmarks.make_experiments_tables > /tmp/tables.md
"""

from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.jsonl")


def load() -> list[dict]:
    with open(RESULTS) as f:
        return [json.loads(line) for line in f]


def fmt_bytes(b: float) -> str:
    return f"{b/2**30:.1f}"


def dryrun_table(recs: list[dict]) -> str:
    out = [
        "| arch | shape | mesh | compile (s) | peak GB/dev | TPU-adj GB | HLO collectives (per-dev MB) | strategy |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("ok"):
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL | — | — | {r.get('error','')[:60]} | — |"
            )
            continue
        colls = ", ".join(
            f"{k.split('-')[1] if '-' in k else k}:{v/2**20:.0f}"
            for k, v in sorted(r["collectives_raw"].items())
        ) or "none"
        strat = r.get("strategy", "tp")
        if r.get("fsdp") and strat == "tp":
            strat = "tp+fsdp"
        if r.get("grad_accum", 1) > 1:
            strat += f",acc{r['grad_accum']}"
        if "float8" in r.get("kv_cache_dtype", ""):
            strat += ",kv-f8"
        adj = r["mem"].get("tpu_adjusted_peak_bytes", r["mem"]["peak_bytes"])
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} | "
            f"{fmt_bytes(r['mem']['peak_bytes'])} | {fmt_bytes(adj)} | {colls} | {strat} |"
        )
    return "\n".join(out)


def roofline_table(recs: list[dict], mesh: str = "16x16") -> str:
    out = [
        "| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | bound | step (ms) | useful | MFU |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("ok") or r["mesh"] != mesh:
            continue
        a = r["analytic"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {a['t_compute']*1e3:.2f} | "
            f"{a['t_memory']*1e3:.2f} | {a['t_collective']*1e3:.2f} | "
            f"**{a['bottleneck']}** | {a['step_time']*1e3:.2f} | "
            f"{a['usefulness']:.2f} | {a['mfu']*100:.1f}% |"
        )
    return "\n".join(out)


def summary(recs: list[dict]) -> str:
    ok = [r for r in recs if r.get("ok")]
    lines = [f"- cells compiled OK: **{len(ok)}/{len(recs)}**"]
    over = [
        f"{r['arch']}/{r['shape']}/{r['mesh']} ({fmt_bytes(r['mem']['peak_bytes'])} GB)"
        for r in ok
        if r["mem"]["peak_bytes"] > 16e9
    ]
    lines.append(
        f"- cells above the 16 GB v5e HBM budget: {len(over)}"
        + (": " + "; ".join(over) if over else "")
    )
    trains = [r for r in ok if r["kind"] == "train" and r["mesh"] == "16x16"]
    if trains:
        mfus = [r["analytic"]["mfu"] for r in trains]
        lines.append(
            f"- single-pod train-cell MFU: mean {100*sum(mfus)/len(mfus):.1f}%, "
            f"min {100*min(mfus):.1f}%, max {100*max(mfus):.1f}%"
        )
    return "\n".join(lines)


def main() -> None:
    recs = load()
    print("### Dry-run summary\n")
    print(summary(recs))
    print("\n### §Dry-run table (all cells, both meshes)\n")
    print(dryrun_table(recs))
    print("\n### §Roofline table (single-pod 16×16 baseline)\n")
    print(roofline_table(recs, "16x16"))
    print("\n### §Roofline table (multi-pod 2×16×16)\n")
    print(roofline_table(recs, "2x16x16"))


if __name__ == "__main__":
    main()
