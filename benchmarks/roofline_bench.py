"""§Roofline table: per (arch × shape) terms from the dry-run JSONL.

Reads benchmarks/results/dryrun.jsonl (produced by repro.launch.dryrun) and
prints the single-pod baseline table + multi-pod summary. If the JSONL is
missing, recomputes the ANALYTIC terms directly (no compile) so the bench
always runs.
"""

from __future__ import annotations

import json
import os

from repro.configs import all_cells, get_arch, get_shape
from repro.roofline.analysis import RooflineTerms
from repro.roofline.flops import count_cell

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.jsonl")


def _terms_from_record(r: dict) -> RooflineTerms:
    a = r["analytic"]
    return RooflineTerms(
        name=f"{r['arch']}/{r['shape']}",
        chips=r["chips"],
        flops=a["flops"],
        hbm_bytes=a["hbm_bytes"],
        coll_bytes=a["coll_bytes"],
        model_flops=a["model_flops"],
    )


def _analytic_terms(arch: str, shape: str, multi: bool) -> RooflineTerms:
    cfg, shp = get_arch(arch), get_shape(shape)
    dp, tp = (32, 16) if multi else (16, 16)
    c = count_cell(cfg, shp, dp=dp, tp=tp)
    return RooflineTerms(
        name=f"{arch}/{shape}",
        chips=dp * tp,
        flops=c.flops,
        hbm_bytes=c.hbm_bytes,
        coll_bytes=c.coll_bytes,
        model_flops=c.model_flops,
    )


def load_terms() -> tuple[list[RooflineTerms], list[RooflineTerms], bool]:
    single, multi = [], []
    if os.path.exists(RESULTS):
        with open(RESULTS) as f:
            for line in f:
                r = json.loads(line)
                if not r.get("ok"):
                    continue
                t = _terms_from_record(r)
                (single if r["mesh"] == "16x16" else multi).append(t)
        if single:
            return single, multi, True
    for arch, shape in all_cells():
        single.append(_analytic_terms(arch, shape, False))
        multi.append(_analytic_terms(arch, shape, True))
    return single, multi, False


def run(csv: bool = True) -> list[tuple[str, float, str]]:
    single, multi, from_dryrun = load_terms()
    rows = []
    print(f"# roofline source: {'compiled dry-run' if from_dryrun else 'analytic only'}")
    print("#", RooflineTerms.header())
    for t in single:
        print("#", t.row())
        rows.append(
            (
                f"roofline_{t.name.replace('/', '_')}_step_ms",
                t.step_time * 1e3,
                f"bound={t.bottleneck} MFU={t.mfu*100:.1f}% useful={t.usefulness:.2f}",
            )
        )
    # aggregate scores
    trains = [t for t in single if "train" in t.name]
    if trains:
        avg_mfu = sum(t.mfu for t in trains) / len(trains)
        rows.append(("roofline_avg_train_MFU", avg_mfu, f"{len(trains)} train cells, single-pod"))
    if csv:
        for n, v, d in rows:
            print(f"{n},{v:.6g},{d}")
    return rows


if __name__ == "__main__":
    run()
