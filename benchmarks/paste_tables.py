"""Paste the generated dry-run/roofline tables into EXPERIMENTS.md at the
<!-- DRYRUN_TABLES --> and <!-- ROOFLINE_TABLES --> markers."""

from __future__ import annotations

import os

from benchmarks.make_experiments_tables import (
    dryrun_table,
    load,
    roofline_table,
    summary,
)

EXP = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "EXPERIMENTS.md")


def main() -> None:
    recs = load()
    dry = (
        summary(recs)
        + "\n\n**All cells (both meshes):**\n\n"
        + dryrun_table(recs)
    )
    roof = (
        "**Single-pod (16×16) baseline — the §Roofline table:**\n\n"
        + roofline_table(recs, "16x16")
        + "\n\n**Multi-pod (2×16×16):**\n\n"
        + roofline_table(recs, "2x16x16")
    )
    text = open(EXP).read()
    text = text.replace("<!-- DRYRUN_TABLES -->", dry)
    text = text.replace("<!-- ROOFLINE_TABLES -->", roof)
    open(EXP, "w").write(text)
    print("EXPERIMENTS.md updated,", len(recs), "records")


if __name__ == "__main__":
    main()
