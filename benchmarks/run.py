"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (values are in the unit named in
each row key; timings in ms/us as suffixed).

  §1 kernels_modes   — Fig. 2 left: six kernels, baseline/SM/MM + energy
  §2 mixed_workload  — Fig. 2 right: CoreMark ∥ vector kernels, MM speedup
  §3 reconfig_cost   — PPA analogue: switch latency, indirection, programs
  §4 roofline_bench  — §Roofline: per-cell terms from the dry-run artifact
"""

from __future__ import annotations

import sys


def main() -> None:
    sections = sys.argv[1:] or ["kernels", "mixed", "reconfig", "roofline", "serving"]
    print("name,value,derived")
    if "kernels" in sections:
        print("# --- Fig2-left: kernels under baseline/SM/MM (modeled v5e) ---")
        from benchmarks.kernels_modes import run as k_run

        k_run()
    if "mixed" in sections:
        print("# --- Fig2-right: mixed scalar-vector workload ---")
        from benchmarks.mixed_workload import run as m_run

        m_run()
    if "reconfig" in sections:
        print("# --- PPA analogue: reconfigurability cost ---")
        from benchmarks.reconfig_cost import run as r_run

        r_run()
    if "roofline" in sections:
        print("# --- Roofline per (arch x shape), single-pod baseline ---")
        from benchmarks.roofline_bench import run as rf_run

        rf_run()
    if "serving" in sections:
        print("# --- Serving: measured engine + modeled production decode ---")
        from benchmarks.serving_bench import run as sv_run

        sv_run()


if __name__ == "__main__":
    main()
