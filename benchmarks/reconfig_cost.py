"""Paper's PPA table analogue: the cost of reconfigurability itself (C4).

Silicon area/f_max have no direct analogue; they map to:
  * mode-switch latency      — MEASURED: remesh + reshard of live state
  * mode indirection         — MEASURED: scheduler/cluster dispatch overhead
    per task vs calling the jitted fn directly (the "+1.4% area" analogue:
    overhead of the added machinery on the hot path)
  * resident-program overhead— MEASURED: split mode keeps 2 compiled
    programs (one per pod shape) vs merge's 1; we report compiled HLO bytes
  * energy delta             — MODELED: SM/MM energy per kernel from the
    v5e energy model (paper: −5% SM / −1% MM worst case −7%)
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Mode, SpatzformerCluster, switch_mode
from repro.core.perfmodel import KernelCost, model_vector_stream

from benchmarks.common import PAPER_KERNELS


def run(csv: bool = True) -> list[tuple[str, float, str]]:
    rows = []

    # ---- mode-switch latency with live state (measured)
    cl = SpatzformerCluster(n_pods=1, pod_shape=(1, 1))
    state = {"w": jax.device_put(jnp.zeros((1024, 1024), jnp.float32))}
    switch_mode(cl, Mode.MERGE, state)  # warm
    lat = []
    for _ in range(5):
        t0 = time.perf_counter()
        state, rep = switch_mode(
            cl, Mode.SPLIT if cl.mode is Mode.MERGE else Mode.MERGE, state
        )
        lat.append(time.perf_counter() - t0)
    rows.append(
        ("mode_switch_latency_ms", float(np.median(lat)) * 1e3,
         f"4MB live state, {rep.gbytes_per_sec:.1f}GB/s reshard")
    )

    # ---- mode indirection overhead (measured): info_for + scheduler walk
    t0 = time.perf_counter()
    n = 10000
    for i in range(n):
        cl.info_for(Mode.MERGE)
    rows.append(
        ("mode_indirection_ns_per_call", (time.perf_counter() - t0) / n * 1e9,
         "hot-path cost of reconfigurability machinery")
    )

    # ---- resident program bytes: 1 fused vs 2 per-pod programs (measured)
    x = jnp.zeros((256, 256), jnp.float32)
    fused = jax.jit(lambda a: (a @ a.T).sum()).lower(x).compile()
    half = jax.jit(lambda a: (a @ a.T).sum()).lower(x[:128]).compile()
    fused_b = len(fused.as_text())
    split_b = 2 * len(half.as_text())
    rows.append(
        ("resident_program_bytes_ratio", split_b / fused_b,
         f"SM {split_b}B vs MM {fused_b}B of HLO")
    )

    # ---- modeled energy deltas (paper: SM -5%, MM -1%, worst -7%)
    for name, cost in PAPER_KERNELS.items():
        half = KernelCost(name, cost.flops / 2, cost.hbm_bytes / 2)
        _, e_sm = model_vector_stream([half], 256)
        e_sm *= 2  # two pods
        _, e_mm = model_vector_stream([cost], 512)
        # the baseline has no mode mux: model it as SM minus the per-launch
        # reconfig bookkeeping (measured above ~ O(100ns) ≈ negligible J)
        rows.append(
            (f"energy_{name}_MM_over_SM", e_mm / e_sm,
             "modeled; <1 = MM saves dispatch/fetch energy")
        )

    if csv:
        for n_, v, d in rows:
            print(f"{n_},{v:.6g},{d}")
    return rows


if __name__ == "__main__":
    run()
