#!/usr/bin/env python
"""Docs lint (the CI fast lane): two checks that keep prose honest.

1. **Stale section references.** Docstrings used to cite §-numbers from a
   pre-repo design doc ("DESIGN.md §2") and tables of a never-committed
   EXPERIMENTS.md ("§Perf", "§Roofline", "§Dry-run"). Those were swept;
   this lint keeps them from coming back. Allowed forms:

   * paper section refs in roman numerals — ``paper §II``, ``§III`` — the
     source paper really has those sections;
   * named DESIGN.md anchors — ``DESIGN.md §"Cluster serving"`` — checked
     below against the actual headings;
   * benchmarks' OWN § numbering (``benchmarks/run.py`` §1-§4 and the
     §Roofline/§Dry-run table *generators* live there by design).

2. **Markdown links.** Every relative link/image in the repo's markdown
   must resolve to an existing file, and every ``DESIGN.md §"..."`` quoted
   anchor must match a real DESIGN.md heading.

Exit 1 with a file:line listing on any violation.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# trees where only paper-roman (§II) and named (§"...") references belong
SWEPT_TREES = ("src", "tests")
STALE = re.compile(r"§\s*\d|§Perf|§Roofline|§Dry-run|EXPERIMENTS")
# stale numeric DESIGN.md refs are banned EVERYWHERE (benchmarks included)
STALE_DESIGN = re.compile(r"DESIGN\.md\s*§\s*\d")

MD_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
NAMED_ANCHOR = re.compile(r'DESIGN\.md\s*§"([^"]+)"')

MD_FILES = [
    p for p in list(ROOT.glob("*.md")) + list(ROOT.glob("docs/**/*.md"))
    if p.name != "ISSUE.md"  # working notes, not shipped docs
]


def stale_refs() -> list[str]:
    out = []
    for tree in SWEPT_TREES:
        for p in sorted((ROOT / tree).rglob("*.py")):
            for i, line in enumerate(p.read_text().splitlines(), 1):
                if STALE.search(line):
                    out.append(f"{p.relative_to(ROOT)}:{i}: stale section ref: {line.strip()}")
    me = Path(__file__).resolve()
    for p in sorted(ROOT.rglob("*.py")) + MD_FILES:
        if any(s in p.parts for s in (".git", ".venv")) or p.resolve() == me:
            continue  # this file quotes the banned forms as examples
        for i, line in enumerate(p.read_text().splitlines(), 1):
            if STALE_DESIGN.search(line):
                out.append(
                    f"{p.relative_to(ROOT)}:{i}: numeric DESIGN.md § ref "
                    f"(use a named anchor): {line.strip()}"
                )
    return out


def broken_links() -> list[str]:
    out = []
    design = (ROOT / "DESIGN.md").read_text()
    headings = [
        h.lstrip("#").strip() for h in design.splitlines() if h.startswith("#")
    ]
    for p in MD_FILES:
        text = p.read_text()
        for i, line in enumerate(text.splitlines(), 1):
            for m in MD_LINK.finditer(line):
                target = m.group(1).split("#")[0]
                if not target or "://" in target or target.startswith("mailto:"):
                    continue
                resolved = (p.parent / target).resolve()
                if not resolved.is_relative_to(ROOT):
                    continue  # GitHub-site-relative (badges etc.), not a repo file
                if not resolved.exists() and not (ROOT / target).exists():
                    out.append(f"{p.relative_to(ROOT)}:{i}: broken link -> {target}")
            for m in NAMED_ANCHOR.finditer(line):
                if not any(m.group(1) in h for h in headings):
                    out.append(
                        f"{p.relative_to(ROOT)}:{i}: DESIGN.md anchor "
                        f"\"{m.group(1)}\" matches no heading"
                    )
    # named anchors inside python docstrings get the same heading check
    for tree in SWEPT_TREES:
        for p in sorted((ROOT / tree).rglob("*.py")):
            for i, line in enumerate(p.read_text().splitlines(), 1):
                for m in NAMED_ANCHOR.finditer(line):
                    if not any(m.group(1) in h for h in headings):
                        out.append(
                            f"{p.relative_to(ROOT)}:{i}: DESIGN.md anchor "
                            f"\"{m.group(1)}\" matches no heading"
                        )
    return out


def main() -> int:
    problems = stale_refs() + broken_links()
    for pr in problems:
        print(pr)
    if problems:
        print(f"\ndocs lint: {len(problems)} problem(s)")
        return 1
    print(f"docs lint: ok ({len(MD_FILES)} markdown files checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
